//! DDP iteration-time simulator (paper §5.3, Fig. 12/16/17).
//!
//! Combines a model's communication profile (Fig. 15) with the multi-rail
//! coordinator: every profile op is allreduced through [`MultiRail`]
//! (timing from the calibrated fabric, payload buffers kept small via the
//! scaled path), and compute is modeled from the per-GPU throughput
//! anchors. Backprop/communication overlap hides a configurable fraction
//! of compute (Horovod pipelines allreduce with gradient production).

use crate::config::Config;
use crate::coordinator::buffer::BufferPool;
use crate::coordinator::multirail::MultiRail;
use crate::coordinator::planner::pipeline::{pipelined_total_us, BUCKET_OVERLAP};
use crate::net::cpu_pool::SchedMode;
use crate::trainer::bucket::{bucket_fingerprint, consume_priority, BucketGuard};
use crate::trainer::comm_profile::CommProfile;
use crate::trainer::sched::{OpDesc, OpQueue, OpTiming, SchedStats};
use crate::Result;

/// Fraction of compute time allreduce can hide behind (tensor-fusion
/// pipelining in Horovod/DDP).
pub const DEFAULT_OVERLAP: f64 = 0.5;

/// Forward share of one iteration's compute (backward ≈ 2× forward, the
/// standard DDP rule of thumb) — how the barrier-free scheduler splits
/// [`CommProfile::compute_us`] into awaited forward steps and producing
/// backward steps.
pub const FWD_FRACTION: f64 = 1.0 / 3.0;

/// Preemption-window cap per op (plans with more rounds still only yield
/// the wire this many times — bounds timeline work on huge plans).
const MAX_WINDOWS: usize = 64;

/// Data-parallel training-speed simulator.
pub struct DdpSim {
    pub profile: CommProfile,
    pub mr: MultiRail,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub batch_per_gpu: usize,
    pub overlap: f64,
    /// Cross-bucket chunk pipelining: consecutive multi-rail bucket ops
    /// overlap (bucket k+1 streams while bucket k's tail reduces). Off by
    /// default — the paper's Fig. 12/16/17 shapes are serial-bucket.
    pub bucket_pipelining: bool,
    /// Real elements per simulated op payload (timing is scaled to the
    /// profile's byte sizes; numerics stay real but small).
    sim_elems: usize,
    /// Recycled staging buffers: every bucket op re-fills one pooled
    /// buffer in place instead of allocating nodes × sim_elems per op.
    pool: BufferPool,
    /// Trainer-level containment guard: each reduced bucket's gradient
    /// fingerprint is checked against a fault-free oracle; a mismatch
    /// triggers a recompute-and-retransmit of that bucket over the
    /// checksum-verified plane before the gradient touches weights.
    pub guard: Option<BucketGuard>,
    /// Fingerprints of the reduced buckets from the most recent
    /// [`DdpSim::comm_us`] call, in iteration order — a clean run's record
    /// serves as the guard's oracle.
    last_fingerprints: Vec<u64>,
    /// Trainer op scheduling (`sched = barrier | priority`).
    pub sched: SchedMode,
    /// The barrier-free wire timeline (priority mode only): ops enqueued
    /// at backward, awaited at the consuming forward step next iteration.
    queue: OpQueue,
    /// Training iterations completed in priority mode.
    iter_idx: u64,
    /// Priority-mode training clock (us): end of the last iteration.
    clock_us: f64,
    /// Per-op (duration, plan rounds, plan epoch) from the most recent
    /// `comm_us` call — the timeline inputs.
    last_timings: Vec<OpTiming>,
}

impl DdpSim {
    pub fn new(cfg: &Config, profile: CommProfile, gpus_per_node: usize, batch_per_gpu: usize) -> Result<DdpSim> {
        let mr = MultiRail::new(cfg)?;
        Ok(DdpSim {
            profile,
            mr,
            nodes: cfg.nodes,
            gpus_per_node,
            batch_per_gpu,
            overlap: DEFAULT_OVERLAP,
            bucket_pipelining: false,
            sim_elems: 1024,
            pool: BufferPool::new(),
            guard: None,
            last_fingerprints: Vec::new(),
            sched: cfg.sched,
            queue: OpQueue::new(cfg.sched),
            iter_idx: 0,
            clock_us: 0.0,
            last_timings: Vec::new(),
        })
    }

    /// Switch the trainer's op scheduling (resets the wire timeline).
    pub fn with_sched(mut self, mode: SchedMode) -> DdpSim {
        self.sched = mode;
        self.queue = OpQueue::new(mode);
        self.iter_idx = 0;
        self.clock_us = 0.0;
        self
    }

    /// Arm the containment guard with per-bucket oracle fingerprints
    /// (typically [`DdpSim::last_fingerprints`] of a fault-free twin).
    pub fn with_fingerprint_guard(mut self, expected: Vec<u64>) -> DdpSim {
        self.guard = Some(BucketGuard::new(expected));
        self
    }

    /// Per-bucket gradient fingerprints from the latest `comm_us` call.
    pub fn last_fingerprints(&self) -> &[u64] {
        &self.last_fingerprints
    }

    /// Buckets the containment guard caught corrupted and recovered.
    pub fn guard_recomputes(&self) -> u64 {
        self.guard.as_ref().map(|g| g.recomputes).unwrap_or(0)
    }

    /// Enable/disable cross-bucket chunk pipelining.
    pub fn with_bucket_pipelining(mut self, on: bool) -> DdpSim {
        self.bucket_pipelining = on;
        self
    }

    /// Attach a node join/leave schedule to the underlying coordinator
    /// (elastic membership: applied at op boundaries as training's
    /// virtual clock passes each event; bucket payloads automatically
    /// follow the surviving node count).
    pub fn with_membership(mut self, schedule: crate::net::fault::MembershipSchedule) -> DdpSim {
        self.mr.set_membership(schedule);
        self
    }

    /// Communication time of one full iteration (all profile ops). Each
    /// bucket op reports `(time, planner-scheduled across ≥2 rails)`; with
    /// bucket pipelining on, adjacent such ops earn the planner's overlap
    /// credit. Forced-dispatch and MPTCP-sliced ops never qualify
    /// (`last_plan` is None there — nothing chunk-pipelines).
    pub fn comm_us(&mut self) -> Result<f64> {
        let n_ops = self.profile.ops.len();
        let mut ops: Vec<(f64, bool)> = Vec::with_capacity(n_ops);
        self.last_fingerprints.clear();
        self.last_timings.clear();
        for (op_idx, &bytes) in self.profile.ops.clone().iter().enumerate() {
            // priority mode tags each collective's host-pool jobs with the
            // bucket's next-forward consumption priority; the tag reorders
            // worker drain only — results stay submission-ordered, so
            // numerics and modeled times are untouched
            self.mr.op_priority = match self.sched {
                SchedMode::Priority => consume_priority(op_idx, n_ops),
                SchedMode::Barrier => 0,
            };
            // staging buffers track the coordinator's surviving node set,
            // not the configured count — membership churn between buckets
            // shrinks/regrows them transparently (poll first so the
            // buffer matches the post-churn count)
            self.mr.poll_membership()?;
            let nodes = self.mr.active_nodes();
            let mut buf = self
                .pool
                .acquire(nodes, self.sim_elems, |n, i| ((n + i) % 17) as f32);
            let elem_bytes = bytes as f64 / self.sim_elems as f64;
            let mut rep = self.mr.allreduce_scaled(&mut buf, elem_bytes)?;
            let mut fp = bucket_fingerprint(&buf, buf.full_window());
            let want = self
                .guard
                .as_ref()
                .and_then(|g| g.expected.get(op_idx).copied());
            if want.is_some() && want != Some(fp) {
                // containment: the reduced gradient diverged from the
                // fault-free oracle — recompute the bucket from source and
                // retransmit it with the wire checksums forced on,
                // charging the retried op's full modeled time
                self.pool.release(buf);
                let was_integrity = self.mr.fab.integrity;
                self.mr.fab.integrity = true;
                buf = self
                    .pool
                    .acquire(nodes, self.sim_elems, |n, i| ((n + i) % 17) as f32);
                let retry = self.mr.allreduce_scaled(&mut buf, elem_bytes)?;
                self.mr.fab.integrity = was_integrity;
                rep.total_us += retry.total_us;
                self.mr.recycle(retry);
                fp = bucket_fingerprint(&buf, buf.full_window());
                if let Some(g) = self.guard.as_mut() {
                    g.recomputes += 1;
                }
            }
            self.last_fingerprints.push(fp);
            self.pool.release(buf);
            let planned_multirail = self
                .mr
                .last_plan
                .as_ref()
                .map(|p| p.active_rails() >= 2)
                .unwrap_or(false);
            ops.push((rep.total_us, planned_multirail));
            self.last_timings.push(OpTiming {
                dur_us: rep.total_us,
                rounds: self.mr.last_plan_rounds(),
                epoch: self.mr.plan_epoch(),
            });
            self.mr.recycle(rep);
        }
        self.mr.op_priority = 0;
        if self.bucket_pipelining {
            Ok(pipelined_total_us(&ops, BUCKET_OVERLAP))
        } else {
            Ok(ops.iter().map(|(t, _)| *t).sum())
        }
    }

    /// Warm the Load Balancer's data-length table (the paper reports
    /// convergence within the first 100 iterations). In priority mode
    /// this runs full barrier-free iterations so the wire timeline
    /// reaches steady state too — either way, exactly one collective
    /// sequence per iteration, keeping warmed twins comparable
    /// fingerprint-for-fingerprint.
    pub fn warmup(&mut self, iters: usize) -> Result<()> {
        for _ in 0..iters {
            match self.sched {
                SchedMode::Barrier => {
                    self.comm_us()?;
                }
                SchedMode::Priority => {
                    self.priority_iter_us()?;
                }
            }
        }
        Ok(())
    }

    /// The coordinator's schedule-selection epoch: stable while bucket
    /// plans are reused, bumps when the predicted-vs-measured error trips
    /// `replan_error` between buckets (straggler-aware replanning).
    pub fn plan_epoch(&self) -> u64 {
        self.mr.plan_epoch()
    }

    /// One training iteration time (us). Barrier mode: compute + exposed
    /// communication, with every bucket done before the iteration ends.
    /// Priority mode: the barrier-free span (forward awaits last
    /// iteration's in-flight buckets, backward enqueues this iteration's)
    /// — measure after [`DdpSim::warmup`] for steady-state numbers, since
    /// iteration 0 awaits nothing.
    pub fn iter_time_us(&mut self) -> Result<f64> {
        match self.sched {
            SchedMode::Barrier => {
                let compute = self.profile.compute_us(self.batch_per_gpu);
                let comm = self.comm_us()?;
                let exposed = (comm - self.overlap * compute).max(0.0);
                Ok(compute + exposed)
            }
            SchedMode::Priority => self.priority_iter_us(),
        }
    }

    /// One barrier-free iteration (DESIGN.md §13). The forward pass awaits
    /// the previous iteration's buckets at their consuming steps (bucket
    /// produced at backward index j is needed at forward step K-1-j); the
    /// backward pass runs the REAL collectives — in the exact program
    /// order of the barrier baseline, so op epochs, per-rail RNG streams,
    /// numerics and per-op durations are bit-identical — and enqueues each
    /// on the wire timeline at its production instant. Cross-bucket chunk
    /// pipelining is inert here: overlap comes from the timeline itself.
    fn priority_iter_us(&mut self) -> Result<f64> {
        let compute = self.profile.compute_us(self.batch_per_gpu);
        let fwd = FWD_FRACTION * compute;
        let bwd = compute - fwd;
        let k = self.profile.ops.len().max(1);
        let iter = self.iter_idx;
        let fwd_start = self.clock_us;

        // ---- forward: await last iteration's buckets in consumption order
        let mut t = fwd_start;
        let step = fwd / k as f64;
        let mut stall = 0.0;
        if iter > 0 {
            for s in 0..k {
                let produced = k - 1 - s;
                if let Some(done) = self.queue.completion_us(iter - 1, produced) {
                    if done > t {
                        stall += done - t;
                        t = done;
                    }
                }
                t += step;
            }
        } else {
            t += fwd;
        }
        let fwd_end = t;

        // ---- backward: run the collectives, enqueue them as produced
        self.comm_us()?;
        let timings = std::mem::take(&mut self.last_timings);
        for (j, timing) in timings.iter().enumerate() {
            self.queue.enqueue(OpDesc {
                iter,
                bucket: j,
                priority: consume_priority(j, k),
                epoch: timing.epoch,
                // gradients stream out through the backward pass; bucket j
                // of K is produced (j+1)/K of the way through it
                ready_us: fwd_end + bwd * (j + 1) as f64 / k as f64,
                dur_us: timing.dur_us,
                windows: timing.rounds.clamp(1, MAX_WINDOWS),
            });
        }
        self.last_timings = timings;

        let bwd_end = fwd_end + bwd;
        self.queue.note_boundary(bwd_end, iter);
        self.queue.stats.stall_us_last = stall;
        self.queue.stats.stall_us_total += stall;
        self.clock_us = bwd_end;
        self.iter_idx += 1;
        Ok(bwd_end - fwd_start)
    }

    /// Scheduler observability (priority mode; zeros under barrier).
    pub fn sched_stats(&self) -> &SchedStats {
        &self.queue.stats
    }

    /// The wire timeline's live ops (priority mode).
    pub fn queued_ops(&self) -> &[crate::trainer::sched::QueuedOp] {
        self.queue.ops()
    }

    /// Per-op (duration, rounds, epoch) of the latest collective sequence.
    pub fn last_timings(&self) -> &[OpTiming] {
        &self.last_timings
    }

    /// Complete everything still on the wire timeline; true when the
    /// queue fully drained (anything else is a stuck op).
    pub fn drain_queue(&mut self) -> bool {
        self.queue.quiesce();
        self.queue.all_done()
    }

    /// Paper Fig. 12/16/17 metric: samples processed per second per node.
    pub fn samples_per_sec_per_node(&mut self) -> Result<f64> {
        let t = self.iter_time_us()?;
        Ok(self.batch_per_gpu as f64 * self.gpus_per_node as f64 / (t / 1e6))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::net::protocol::ProtoKind;

    fn cfg(combo: &[ProtoKind], nodes: usize, policy: Policy) -> Config {
        Config {
            nodes,
            combo: combo.to_vec(),
            policy,
            deterministic: true,
            ..Config::default()
        }
    }

    #[test]
    fn dual_rail_trains_faster_than_single() {
        let mut dual = DdpSim::new(
            &cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha),
            CommProfile::vgg11(),
            1,
            64,
        )
        .unwrap();
        let mut single = DdpSim::new(
            &cfg(&[ProtoKind::Tcp], 4, Policy::SingleRail),
            CommProfile::vgg11(),
            1,
            64,
        )
        .unwrap();
        dual.warmup(3).unwrap();
        let d = dual.samples_per_sec_per_node().unwrap();
        let s = single.samples_per_sec_per_node().unwrap();
        assert!(d > s * 1.1, "dual {d} single {s}");
    }

    #[test]
    fn more_gpus_more_throughput() {
        let mk = |gpus| {
            DdpSim::new(
                &cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha),
                CommProfile::alexnet(),
                gpus,
                32,
            )
            .unwrap()
        };
        let g1 = mk(1).samples_per_sec_per_node().unwrap();
        let g2 = mk(2).samples_per_sec_per_node().unwrap();
        assert!(g2 > 1.3 * g1, "g1 {g1} g2 {g2}");
    }

    #[test]
    fn bucket_pipelining_helps_multirail_and_is_bounded() {
        let mk = |pipelined| {
            DdpSim::new(
                &cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha),
                CommProfile::vgg11(),
                1,
                64,
            )
            .unwrap()
            .with_bucket_pipelining(pipelined)
        };
        let mut serial = mk(false);
        let mut pipe = mk(true);
        serial.warmup(3).unwrap();
        pipe.warmup(3).unwrap();
        let cs = serial.comm_us().unwrap();
        let cp = pipe.comm_us().unwrap();
        assert!(cp < cs, "pipelined {cp} vs serial {cs}");
        // the credit is bounded: never better than a 50% cut
        assert!(cp > 0.5 * cs, "pipelined {cp} vs serial {cs}");
    }

    #[test]
    fn forced_flat_dispatch_gets_no_pipeline_credit() {
        // fixed dispatch has no chunk streams, so pipelining must be inert
        use crate::config::PlannerMode;
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.planner = PlannerMode::Flat;
        let mk = |pipelined| {
            DdpSim::new(&c, CommProfile::alexnet(), 1, 32)
                .unwrap()
                .with_bucket_pipelining(pipelined)
        };
        let cs = mk(false).comm_us().unwrap();
        let cp = mk(true).comm_us().unwrap();
        assert_eq!(cs, cp);
    }

    #[test]
    fn single_rail_gets_no_pipeline_credit() {
        let mk = |pipelined| {
            DdpSim::new(
                &cfg(&[ProtoKind::Tcp], 4, Policy::SingleRail),
                CommProfile::alexnet(),
                1,
                32,
            )
            .unwrap()
            .with_bucket_pipelining(pipelined)
        };
        let cs = mk(false).comm_us().unwrap();
        let cp = mk(true).comm_us().unwrap();
        assert_eq!(cs, cp);
    }

    #[test]
    fn straggler_mid_training_replans_between_buckets() {
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.control.timer_window = 3;
        c.control.replan_error = 0.15;
        // drop the 512KB ops: they sit on the cold/hot threshold, and this
        // test is about replan triggers, not threshold flips
        let mut profile = CommProfile::vgg11();
        profile.ops.retain(|&b| b >= 2 << 20);
        let mut sim = DdpSim::new(&c, profile, 1, 64).unwrap();
        // long warmup: balancer corrections converge, all size classes
        // have cached plans
        sim.warmup(12).unwrap();
        let settled = sim.plan_epoch();
        // without a straggler the cached bucket plans keep being reused
        sim.warmup(3).unwrap();
        assert_eq!(sim.plan_epoch(), settled, "replanned without divergence");
        // a rail turning into a straggler mid-training must trip the
        // predicted-vs-measured replan trigger between buckets
        sim.mr.fab.inject_straggler(0, 4_000.0, 0.0);
        sim.warmup(8).unwrap();
        assert!(
            sim.plan_epoch() > settled,
            "mid-training straggler must force a replan"
        );
    }

    #[test]
    fn node_leave_mid_training_shrinks_set_and_replans() {
        use crate::net::fault::MembershipSchedule;
        let mut sim = DdpSim::new(
            &cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha),
            CommProfile::alexnet(),
            1,
            32,
        )
        .unwrap()
        // node 3 departs 1us into training: the event lands mid-bucket
        // and is applied at the next bucket boundary
        .with_membership(MembershipSchedule::none().leave(3, 1.0));
        assert_eq!(sim.mr.membership_epoch(), 0);
        let e_plan = sim.plan_epoch();
        let c1 = sim.comm_us().unwrap();
        assert!(c1 > 0.0);
        // the leave applied during the first iteration's bucket stream
        assert_eq!(sim.mr.active_nodes(), 3);
        assert_eq!(sim.mr.membership_epoch(), 1);
        assert!(
            sim.plan_epoch() > e_plan,
            "membership rebind must start a fresh selection epoch"
        );
        assert!(sim.mr.exceptions.membership_within_budget());
        // training continues on the surviving set
        let c2 = sim.comm_us().unwrap();
        assert!(c2 > 0.0);
        assert_eq!(sim.mr.active_nodes(), 3);
    }

    #[test]
    fn fingerprint_guard_contains_poisoned_buckets() {
        use crate::net::fault::CorruptSchedule;
        // fault-free oracle records the per-bucket gradient fingerprints
        let mut oracle = DdpSim::new(
            &cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha),
            CommProfile::alexnet(),
            1,
            32,
        )
        .unwrap();
        oracle.comm_us().unwrap();
        let expect = oracle.last_fingerprints().to_vec();
        assert!(!expect.is_empty());

        // corrupted fabric with the wire checksums ablated: poison reaches
        // the reduction, and only the trainer guard stands before weights
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.corrupt = CorruptSchedule::none().flip(1, 0.0, 1e12, 0.35);
        c.integrity = false;

        // unguarded twin of the corrupted config diverges from the oracle
        let mut bare = DdpSim::new(&c, CommProfile::alexnet(), 1, 32).unwrap();
        bare.comm_us().unwrap();
        assert_ne!(
            bare.last_fingerprints(),
            &expect[..],
            "silent corruption must poison some reduced bucket"
        );

        // guarded run: every poisoned bucket is caught, recomputed, and
        // retransmitted over the checksum-verified plane
        let mut sim = DdpSim::new(&c, CommProfile::alexnet(), 1, 32)
            .unwrap()
            .with_fingerprint_guard(expect.clone());
        let t = sim.comm_us().unwrap();
        assert!(t > 0.0);
        assert!(sim.guard_recomputes() > 0, "poison must trip the guard");
        assert_eq!(
            sim.last_fingerprints(),
            &expect[..],
            "containment must restore every bucket to the oracle gradient"
        );
    }

    #[test]
    fn fingerprint_guard_is_idle_on_clean_runs() {
        let mk = || {
            DdpSim::new(
                &cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha),
                CommProfile::vgg11(),
                1,
                64,
            )
            .unwrap()
        };
        let mut oracle = mk();
        oracle.comm_us().unwrap();
        let expect = oracle.last_fingerprints().to_vec();
        let mut guarded = mk().with_fingerprint_guard(expect.clone());
        let mut plain = mk();
        let tg = guarded.comm_us().unwrap();
        let tp = plain.comm_us().unwrap();
        assert_eq!(guarded.guard_recomputes(), 0, "clean run must not trip");
        assert_eq!(guarded.last_fingerprints(), &expect[..]);
        assert_eq!(tg, tp, "an idle guard must not perturb modeled time");
    }

    #[test]
    fn priority_sched_bit_identical_and_faster_than_barrier() {
        let base = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut pcfg = base.clone();
        pcfg.sched = SchedMode::Priority;
        let mut barrier = DdpSim::new(&base, CommProfile::alexnet(), 1, 32).unwrap();
        let mut priority = DdpSim::new(&pcfg, CommProfile::alexnet(), 1, 32).unwrap();
        barrier.warmup(3).unwrap();
        priority.warmup(3).unwrap();
        let (mut bt, mut pt) = (0.0, 0.0);
        for it in 0..3 {
            bt += barrier.iter_time_us().unwrap();
            pt += priority.iter_time_us().unwrap();
            assert_eq!(
                barrier.last_fingerprints(),
                priority.last_fingerprints(),
                "gradients diverged at measured iteration {it}"
            );
        }
        // alexnet at 4 nodes on tcp-tcp is comm-bound: the barrier-free
        // span must beat compute + exposed-comm
        assert!(pt < bt, "priority {pt} vs barrier {bt}");
        // the win is real overlap: ops in flight across a boundary
        assert!(priority.sched_stats().boundary_in_flight_max >= 1);
        assert!(priority.sched_stats().cross_boundary_ops >= 1);
        assert!(priority.drain_queue(), "wire timeline must drain");
    }

    #[test]
    fn comm_time_positive_and_repeatable_shape() {
        let mut sim = DdpSim::new(
            &cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha),
            CommProfile::alexnet(),
            1,
            32,
        )
        .unwrap();
        let c1 = sim.comm_us().unwrap();
        assert!(c1 > 0.0);
        // warmed balancer should not be slower than the first pass
        sim.warmup(3).unwrap();
        let c2 = sim.comm_us().unwrap();
        assert!(c2 <= c1 * 1.1, "c1 {c1} c2 {c2}");
    }
}
