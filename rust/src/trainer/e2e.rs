//! REAL end-to-end data-parallel training (the mandated e2e validation).
//!
//! All three layers compose here, with Python nowhere on the path:
//!
//! 1. each simulated worker runs the AOT-compiled L2 train step
//!    (`train_step_<model>.hlo.txt`, containing the L1 Pallas matmul
//!    kernels) on its own synthetic token batch via PJRT;
//! 2. the per-worker gradient vectors are allreduced **through Nezha's
//!    multi-rail coordinator** bucket by bucket — real bytes, reduced by
//!    the Pallas `add_pair` kernel when `use_pjrt_reducer` is set;
//! 3. the averaged gradient feeds the AOT Pallas fused-SGD update.
//!
//! Because every replica starts from identical parameters and applies the
//! identical averaged gradient, replicas stay bit-identical; we exploit
//! that to store one parameter copy (standard DDP-simulation trick) while
//! still executing the N per-worker forward/backward passes.

use std::sync::Arc;

use crate::config::Config;
use crate::coordinator::buffer::UnboundBuffer;
use crate::coordinator::multirail::MultiRail;
use crate::runtime::{Engine, ModelRunner, PjrtReducer};
use crate::trainer::bucket::Bucketizer;
use crate::util::rng::Pcg;
use crate::Result;

/// End-to-end run configuration.
#[derive(Debug, Clone)]
pub struct E2EConfig {
    pub model: String,
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    /// Gradient fusion bucket size (elements).
    pub bucket_elems: usize,
    pub log_every: usize,
    /// Reduce through the AOT Pallas kernel (vs portable rust loop).
    pub use_pjrt_reducer: bool,
    pub seed: u64,
}

impl Default for E2EConfig {
    fn default() -> Self {
        E2EConfig {
            model: "tiny".into(),
            steps: 50,
            lr: 0.05,
            momentum: 0.9,
            bucket_elems: 4 * 1024 * 1024,
            log_every: 10,
            use_pjrt_reducer: true,
            seed: 7,
        }
    }
}

/// One logged step.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    /// Mean loss across workers.
    pub loss: f32,
    /// Modeled multi-rail communication time for this step (us).
    pub comm_us: f64,
    /// Wall-clock compute time for the N train-step executions (us).
    pub compute_wall_us: f64,
    pub failovers: usize,
}

/// Synthetic corpus: a deterministic zipf-ish token stream with local
/// correlations (so the model has something learnable).
pub fn synth_batch(rng: &mut Pcg, batch: usize, seq1: usize, vocab: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(batch * seq1);
    for _ in 0..batch {
        let mut prev: i32 = rng.below(vocab as u64) as i32;
        for t in 0..seq1 {
            // markov-ish: repeat/increment previous token often
            let u = rng.f64();
            let tok = if u < 0.35 {
                prev
            } else if u < 0.6 {
                (prev + 1) % vocab as i32
            } else {
                let z = rng.f64();
                ((z * z * (vocab as f64 - 1.0)) as i32).min(vocab as i32 - 1)
            };
            out.push(tok);
            prev = tok;
            let _ = t;
        }
    }
    out
}

/// Run the end-to-end training loop; returns the per-step log.
pub fn train_e2e(cfg: &Config, e2e: &E2EConfig) -> Result<Vec<StepLog>> {
    let engine = Arc::new(Engine::new(&cfg.artifacts_dir)?);
    let runner = ModelRunner::new(engine.clone(), &e2e.model)?;
    runner.warmup()?;
    let mut mr = MultiRail::new(cfg)?;
    if e2e.use_pjrt_reducer {
        mr = mr.with_reducer(Box::new(PjrtReducer::new(engine.clone())?));
    }
    let n = cfg.nodes;
    let padded = runner.spec.padded;
    let buckets = Bucketizer::new(padded, e2e.bucket_elems);

    let mut params = runner.init_params()?;
    let mut vel = vec![0.0f32; padded];
    let mut rng = Pcg::new(e2e.seed);
    let mut logs = Vec::with_capacity(e2e.steps);

    for step in 0..e2e.steps {
        // 1. per-worker forward/backward (real PJRT executions)
        let t0 = std::time::Instant::now();
        let mut losses = Vec::with_capacity(n);
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(n);
        for w in 0..n {
            let mut wrng = rng.split(w as u64 + 1);
            let tokens = synth_batch(
                &mut wrng,
                runner.spec.batch,
                runner.spec.seq_len + 1,
                runner.spec.vocab,
            );
            let (loss, g) = runner.train_step(&params, &tokens)?;
            losses.push(loss);
            grads.push(g);
        }
        let compute_wall_us = t0.elapsed().as_secs_f64() * 1e6;

        // 2. multi-rail allreduce, bucket by bucket (real bytes)
        let mut buf = UnboundBuffer::new(std::mem::take(&mut grads));
        let mut comm_us = 0.0;
        let mut failovers = 0;
        for w in &buckets.windows {
            // carve a sub-buffer view via the shared window; MultiRail
            // operates on full buffers, so allreduce the window by
            // temporarily treating it as the op payload
            let rep = mr.allreduce_window(&mut buf, *w)?;
            comm_us += rep.total_us;
            failovers += rep.failovers;
        }
        let mut reduced = buf.into_data();

        // 3. average + fused Pallas SGD update (identical on all replicas)
        let g_avg = {
            let g0 = &mut reduced[0];
            let inv = 1.0 / n as f32;
            for v in g0.iter_mut() {
                *v *= inv;
            }
            g0.clone()
        };
        let (p2, v2) = runner.sgd_update(&params, &g_avg, &vel, e2e.lr, e2e.momentum)?;
        params = p2;
        vel = v2;

        // advance the data stream
        rng = rng.split(0xABCD + step as u64);
        let loss = losses.iter().sum::<f32>() / n as f32;
        logs.push(StepLog { step, loss, comm_us, compute_wall_us, failovers });
        if e2e.log_every > 0 && step % e2e.log_every == 0 {
            crate::info!(
                "step {step:4}  loss {loss:.4}  comm {:.1}ms  compute {:.0}ms",
                comm_us / 1e3,
                compute_wall_us / 1e3
            );
        }
    }
    Ok(logs)
}
