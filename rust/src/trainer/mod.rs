//! Data-parallel training over Nezha (paper §5.3).
//!
//! * [`comm_profile`] — per-model allreduce size/frequency profiles
//!   (Fig. 15) driving the application-level studies.
//! * [`bucket`] — gradient bucketing/fusion for the real training loop.
//! * [`ddp`] — the DDP iteration-time simulator behind Fig. 12/16/17.
//! * [`sched`] — the barrier-free cross-iteration op-queue (§13):
//!   enqueue-at-backward / await-at-next-forward wire timeline with
//!   priority preemption at window boundaries.
//! * [`e2e`] — the REAL end-to-end loop: AOT train step (PJRT) +
//!   multi-rail allreduce with real gradient bytes + Pallas SGD update.
//! * [`vtrain`] — the vTrain-style GPT-3 schedule replay (Table 3,
//!   Fig. 18/19).

pub mod bucket;
pub mod comm_profile;
pub mod ddp;
pub mod e2e;
pub mod sched;
pub mod vtrain;

pub use comm_profile::CommProfile;
pub use ddp::DdpSim;
pub use sched::{OpQueue, SchedStats};
pub use e2e::{train_e2e, E2EConfig, StepLog};
pub use vtrain::{GptModel, VtrainSim};
