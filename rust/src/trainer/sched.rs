//! Barrier-free cross-iteration op scheduling (DESIGN.md §13).
//!
//! The [`OpQueue`] is the trainer's modeled wire-occupancy timeline: the
//! backward pass *enqueues* each bucket's collective at its production
//! instant, the next iteration's forward pass *awaits* each bucket at the
//! step that consumes it, and in between the wire serves ops one window
//! quantum at a time in ascending (priority, submission) order — so an
//! early-forward (late-produced) bucket preempts a late-forward one at
//! the next window boundary instead of waiting behind it.
//!
//! Crucially the queue only re-composes *when* already-determined per-op
//! durations occupy the wire: the collectives themselves run in the same
//! program order as the barrier baseline (identical op epochs, identical
//! per-rail RNG streams, identical numerics AND per-op durations), so
//! preemption reorders wire time, never reduction results.

use crate::net::cpu_pool::SchedMode;

/// Completion-time comparison slack (timeline values are O(1e5) us).
const EPS_US: f64 = 1e-9;

/// One collective op's timing inputs, as measured by the coordinator
/// (`DdpSim` collects one per bucket per iteration).
#[derive(Debug, Clone, Copy)]
pub struct OpTiming {
    /// Full modeled duration of the op (us), including retries/failover.
    pub dur_us: f64,
    /// Rail rounds of the plan behind it — the preemption window count.
    pub rounds: usize,
    /// Plan-cache selection epoch the op executed under.
    pub epoch: u64,
}

/// Enqueue descriptor for one bucket's collective.
#[derive(Debug, Clone, Copy)]
pub struct OpDesc {
    /// Training iteration that produced the bucket.
    pub iter: u64,
    /// Bucket production index within the iteration.
    pub bucket: usize,
    /// Wire priority (= consumption position next forward; 0 first).
    pub priority: u32,
    /// Plan-cache selection epoch the collective executed under.
    pub epoch: u64,
    /// Wire-time instant the bucket's gradient is produced (us).
    pub ready_us: f64,
    /// Modeled duration on the wire (us).
    pub dur_us: f64,
    /// Preemption windows (plan rounds): the op yields the wire at each
    /// window boundary, never inside one.
    pub windows: usize,
}

/// One op on the modeled wire.
#[derive(Debug, Clone)]
pub struct QueuedOp {
    pub seq: u64,
    pub iter: u64,
    pub bucket: usize,
    pub priority: u32,
    pub epoch: u64,
    pub ready_us: f64,
    pub dur_us: f64,
    quantum_us: f64,
    remaining_us: f64,
    pub done_us: Option<f64>,
}

/// Scheduler observability: enough to assert overlap is real.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    pub ops_enqueued: u64,
    /// Window boundaries where a different op took the wire while another
    /// was mid-flight.
    pub preemptions: u64,
    /// Ops still in flight at the most recent iteration boundary.
    pub boundary_in_flight_last: usize,
    /// Max of the above over the run — ≥ 1 proves cross-iteration overlap.
    pub boundary_in_flight_max: usize,
    /// Total ops that were in flight across some iteration boundary.
    pub cross_boundary_ops: u64,
    /// Forward-stall time waiting on awaited buckets, last iteration (us).
    pub stall_us_last: f64,
    /// Cumulative forward-stall time (us).
    pub stall_us_total: f64,
}

/// The modeled wire timeline (see module docs). `Barrier` mode serves ops
/// strictly FIFO — useful for invariant tests; the trainer's barrier path
/// doesn't build a queue at all.
#[derive(Debug, Clone)]
pub struct OpQueue {
    pub mode: SchedMode,
    wire_now_us: f64,
    ops: Vec<QueuedOp>,
    next_seq: u64,
    /// Seq of the op that held the wire at the last served quantum.
    running: Option<u64>,
    pub stats: SchedStats,
}

impl OpQueue {
    pub fn new(mode: SchedMode) -> OpQueue {
        OpQueue {
            mode,
            wire_now_us: 0.0,
            ops: Vec::new(),
            next_seq: 0,
            running: None,
            stats: SchedStats::default(),
        }
    }

    /// Put one bucket's collective on the wire timeline.
    pub fn enqueue(&mut self, d: OpDesc) {
        let windows = d.windows.max(1);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.stats.ops_enqueued += 1;
        let done_us = if d.dur_us <= 0.0 { Some(d.ready_us) } else { None };
        self.ops.push(QueuedOp {
            seq,
            iter: d.iter,
            bucket: d.bucket,
            priority: d.priority,
            epoch: d.epoch,
            ready_us: d.ready_us,
            dur_us: d.dur_us,
            quantum_us: d.dur_us / windows as f64,
            remaining_us: d.dur_us,
            done_us,
        });
    }

    /// Serve one window quantum (or jump the idle wire to the earliest
    /// readiness instant). Returns false once every op is complete.
    fn step(&mut self) -> bool {
        let mut pick: Option<usize> = None;
        let mut next_ready = f64::INFINITY;
        for (i, o) in self.ops.iter().enumerate() {
            if o.done_us.is_some() {
                continue;
            }
            if o.ready_us > self.wire_now_us + EPS_US {
                next_ready = next_ready.min(o.ready_us);
                continue;
            }
            let better = match pick {
                None => true,
                Some(p) => {
                    let p = &self.ops[p];
                    match self.mode {
                        SchedMode::Priority => (o.priority, o.seq) < (p.priority, p.seq),
                        SchedMode::Barrier => o.seq < p.seq,
                    }
                }
            };
            if better {
                pick = Some(i);
            }
        }
        match pick {
            Some(i) => {
                let seq = self.ops[i].seq;
                if self.running != Some(seq) {
                    // another op takes the wire at this window boundary;
                    // it's a preemption when some op sits mid-flight
                    let mid_flight = self.ops.iter().any(|o| {
                        o.done_us.is_none() && o.remaining_us < o.dur_us && o.seq != seq
                    });
                    if mid_flight {
                        self.stats.preemptions += 1;
                    }
                    self.running = Some(seq);
                }
                let o = &mut self.ops[i];
                let dt = o.quantum_us.min(o.remaining_us);
                self.wire_now_us += dt;
                o.remaining_us -= dt;
                if o.remaining_us <= EPS_US {
                    o.remaining_us = 0.0;
                    o.done_us = Some(self.wire_now_us);
                    self.running = None;
                }
                true
            }
            None if next_ready.is_finite() => {
                self.wire_now_us = next_ready;
                self.running = None;
                true
            }
            None => false,
        }
    }

    /// Completion time of `(iter, bucket)`, serving the wire as far as
    /// needed. None if the op was never enqueued (e.g. iteration 0's
    /// forward awaits nothing).
    pub fn completion_us(&mut self, iter: u64, bucket: usize) -> Option<f64> {
        loop {
            match self.ops.iter().find(|o| o.iter == iter && o.bucket == bucket) {
                None => return None,
                Some(o) => {
                    if let Some(t) = o.done_us {
                        return Some(t);
                    }
                }
            }
            if !self.step() {
                return None;
            }
        }
    }

    /// Serve the wire up to instant `t` (it may overrun `t` by less than
    /// one window — preemption never lands inside a quantum).
    fn advance_to(&mut self, t: f64) {
        while self.wire_now_us < t {
            let has_work = self
                .ops
                .iter()
                .any(|o| o.done_us.is_none() && o.ready_us < t);
            if !has_work || !self.step() {
                break;
            }
        }
        if self.wire_now_us < t {
            self.wire_now_us = t;
        }
    }

    /// Record overlap stats at the boundary ending iteration `iter` (at
    /// wire instant `t` = backward end) and retire ops no future forward
    /// can await (completed, produced before `iter`).
    pub fn note_boundary(&mut self, t: f64, iter: u64) {
        self.advance_to(t);
        let still_open = |o: &QueuedOp| match o.done_us {
            None => true,
            Some(d) => d > t + EPS_US,
        };
        let in_flight = self.ops.iter().filter(|o| still_open(o)).count();
        let crossing = self
            .ops
            .iter()
            .filter(|o| o.iter == iter && still_open(o))
            .count();
        self.stats.boundary_in_flight_last = in_flight;
        self.stats.boundary_in_flight_max = self.stats.boundary_in_flight_max.max(in_flight);
        self.stats.cross_boundary_ops += crossing as u64;
        self.ops.retain(|o| o.done_us.is_none() || o.iter >= iter);
    }

    /// Complete every queued op; returns the final wire instant.
    pub fn quiesce(&mut self) -> f64 {
        while self.step() {}
        self.ops
            .iter()
            .filter_map(|o| o.done_us)
            .fold(self.wire_now_us, f64::max)
    }

    /// True when no op is left incomplete (after [`OpQueue::quiesce`],
    /// anything else is a stuck queue).
    pub fn all_done(&self) -> bool {
        self.ops.iter().all(|o| o.done_us.is_some())
    }

    /// Ops not yet complete on the modeled wire.
    pub fn in_flight(&self) -> usize {
        self.ops.iter().filter(|o| o.done_us.is_none()).count()
    }

    /// The ops currently tracked (completed-but-awaitable and in-flight).
    pub fn ops(&self) -> &[QueuedOp] {
        &self.ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn desc(iter: u64, bucket: usize, priority: u32, ready: f64, dur: f64, windows: usize) -> OpDesc {
        OpDesc { iter, bucket, priority, epoch: 0, ready_us: ready, dur_us: dur, windows }
    }

    #[test]
    fn priority_mode_reorders_barrier_mode_is_fifo() {
        // two ops ready together: priority 0 (enqueued second) first
        let mut q = OpQueue::new(SchedMode::Priority);
        q.enqueue(desc(0, 0, 5, 0.0, 100.0, 4));
        q.enqueue(desc(0, 1, 0, 0.0, 50.0, 2));
        assert_eq!(q.completion_us(0, 1), Some(50.0));
        assert_eq!(q.completion_us(0, 0), Some(150.0));

        let mut q = OpQueue::new(SchedMode::Barrier);
        q.enqueue(desc(0, 0, 5, 0.0, 100.0, 4));
        q.enqueue(desc(0, 1, 0, 0.0, 50.0, 2));
        assert_eq!(q.completion_us(0, 0), Some(100.0));
        assert_eq!(q.completion_us(0, 1), Some(150.0));
    }

    #[test]
    fn preemption_happens_only_at_window_boundaries() {
        // A: prio 5, dur 100 in 10-us windows, ready at 0
        // B: prio 0, dur 50, ready at 25 → takes the wire at the t=30
        //    boundary, NOT at 25 (no mid-window preemption)
        let mut q = OpQueue::new(SchedMode::Priority);
        q.enqueue(desc(0, 0, 5, 0.0, 100.0, 10));
        q.enqueue(desc(0, 1, 0, 25.0, 50.0, 5));
        assert_eq!(q.completion_us(0, 1), Some(80.0), "30 + 50");
        assert_eq!(q.completion_us(0, 0), Some(150.0), "resumes after B");
        assert!(q.stats.preemptions >= 1);
    }

    #[test]
    fn total_wire_time_is_priority_invariant() {
        // same ops, any priorities: the wire finishes at the same instant
        // (preemption reorders occupancy, never total work)
        let durs = [40.0, 25.0, 60.0, 10.0];
        let mut ends = Vec::new();
        for mode in [SchedMode::Barrier, SchedMode::Priority] {
            let mut q = OpQueue::new(mode);
            for (i, &d) in durs.iter().enumerate() {
                q.enqueue(desc(0, i, (durs.len() - i) as u32, 0.0, d, 4));
            }
            ends.push(q.quiesce());
            assert!(q.all_done());
        }
        assert!((ends[0] - ends[1]).abs() < 1e-6);
        assert!((ends[0] - durs.iter().sum::<f64>()).abs() < 1e-6);
    }

    #[test]
    fn idle_wire_jumps_to_next_ready() {
        let mut q = OpQueue::new(SchedMode::Priority);
        q.enqueue(desc(0, 0, 0, 100.0, 20.0, 2));
        assert_eq!(q.completion_us(0, 0), Some(120.0));
        // zero-duration ops complete at their readiness instant
        q.enqueue(desc(0, 1, 0, 200.0, 0.0, 1));
        assert_eq!(q.completion_us(0, 1), Some(200.0));
    }

    #[test]
    fn boundary_counts_cross_iteration_overlap_and_prunes() {
        let mut q = OpQueue::new(SchedMode::Priority);
        q.enqueue(desc(0, 0, 1, 0.0, 30.0, 3));
        q.enqueue(desc(0, 1, 0, 10.0, 80.0, 4));
        // boundary at t=50: bucket 0 done (t=30..? — bucket 1 preempts at
        // t=10? no: prio 0 ready at 10, boundary windows at 10,20,30) —
        // regardless, bucket 1 (dur 80) cannot be done by t=50
        q.note_boundary(50.0, 0);
        assert!(q.stats.boundary_in_flight_last >= 1);
        assert!(q.stats.boundary_in_flight_max >= 1);
        assert!(q.stats.cross_boundary_ops >= 1);
        // awaiting the in-flight op after the boundary still resolves
        let done = q.completion_us(0, 1).unwrap();
        assert!(done > 50.0);
        // a later boundary retires completed older-iteration ops (bucket 1
        // was done by then and vanishes; bucket 0 may still be mid-window)
        q.note_boundary(done + 1.0, 1);
        assert!(!q.ops().iter().any(|o| o.bucket == 1 && o.done_us.is_some()));
        q.quiesce();
        assert!(q.all_done());
    }

    #[test]
    fn completion_of_unknown_op_is_none() {
        let mut q = OpQueue::new(SchedMode::Priority);
        assert_eq!(q.completion_us(3, 7), None);
    }
}
