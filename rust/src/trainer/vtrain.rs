//! vTrain-style GPT-3 training replay (paper §5.3.4, Table 3,
//! Fig. 18/19).
//!
//! vTrain virtually executes the CUDA graph on CPUs, reading a
//! pre-measured per-op overhead table, while issuing communication with
//! real packet sizes and timing. We reproduce the methodology: compute
//! time comes from a per-model overhead constant; the data-parallel
//! gradient allreduce actually runs through the multi-rail coordinator on
//! the supercomputer fabric (1 Gbps Ethernet + IB throttled to 1 Gbps,
//! as in the paper).
//!
//! Bandwidth-limited single-rail runs suffer packet collisions and
//! retransmissions at scale (the paper's explanation for Nezha exceeding
//! the theoretical 2× at 128 nodes); we model that as a congestion
//! penalty growing with the DP group size on saturated rails.

use crate::config::{Config, Policy};
use crate::coordinator::buffer::BufferPool;
use crate::coordinator::collective::Algo;
use crate::coordinator::multirail::MultiRail;
use crate::net::protocol::ProtoKind;
use crate::net::topology::ClusterSpec;
use crate::Result;

/// GPT-3 variant under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GptModel {
    Gpt2_7B,
    Gpt30B,
}

impl GptModel {
    pub fn n_params(self) -> u64 {
        match self {
            GptModel::Gpt2_7B => 2_700_000_000,
            GptModel::Gpt30B => 30_000_000_000,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GptModel::Gpt2_7B => "GPT-3 2.7B",
            GptModel::Gpt30B => "GPT-3 30B",
        }
    }

    /// Virtual compute overhead per sample (us) on 2×V100 nodes — the
    /// "pre-measured overhead table" aggregate.
    fn compute_us_per_sample(self) -> f64 {
        match self {
            GptModel::Gpt2_7B => 1_800.0,
            GptModel::Gpt30B => 16_000.0,
        }
    }
}

/// Table 3 parallel configuration for a node count.
#[derive(Debug, Clone, Copy)]
pub struct ParallelCfg {
    pub nodes: usize,
    pub tp: usize,
    pub dp: usize,
    pub pp: usize,
    pub batch: usize,
}

impl ParallelCfg {
    /// Paper Table 3 (2 V100 per node).
    pub fn for_nodes(nodes: usize) -> ParallelCfg {
        let (tp, dp, pp, batch) = match nodes {
            16 => (2, 2, 8, 128),
            32 => (2, 4, 8, 512),
            64 => (2, 8, 8, 512),
            128 => (2, 16, 8, 512),
            n => (2, (n / 16).max(1), 8, 512),
        };
        ParallelCfg { nodes, tp, dp, pp, batch }
    }

    /// Data-parallel gradient bytes each DP rank must allreduce.
    pub fn dp_grad_bytes(&self, model: GptModel) -> u64 {
        model.n_params() * 4 / (self.tp as u64 * self.pp as u64)
    }
}

/// The replay harness.
pub struct VtrainSim {
    pub model: GptModel,
    pub cfg: ParallelCfg,
    pub policy: Policy,
    /// Ring_Chunked pipeline chunk size in MODELED bytes (None = plain
    /// Ring). Translated to real-buffer chunk elements per packet.
    pub chunk_bytes: Option<u64>,
    mr: MultiRail,
    sim_elems: usize,
    /// Recycled staging buffers for the per-packet replay ops.
    pool: BufferPool,
}

/// Packets above this are split (the paper splits >1 GB payloads into
/// 256 MB packets after the Gloo segfault).
pub const PACKET_SPLIT_BYTES: u64 = 256 * 1024 * 1024;

impl VtrainSim {
    pub fn new(
        model: GptModel,
        nodes: usize,
        policy: Policy,
        chunk_bytes: Option<u64>,
    ) -> Result<VtrainSim> {
        let cfg = ParallelCfg::for_nodes(nodes);
        // supercomputer fabric: 1 Gbps Eth + IB throttled to 1 Gbps.
        // Dual-rail policies use both; single-rail (Gloo) uses one.
        let combo = match policy {
            Policy::SingleRail => vec![ProtoKind::Tcp],
            _ => vec![ProtoKind::Tcp, ProtoKind::Tcp],
        };
        let mut conf = Config {
            cluster: throttled_supercomputer(),
            nodes: cfg.dp.max(2),
            combo,
            policy,
            deterministic: true,
            ..Config::default()
        };
        conf.control.timer_window = 10;
        let mr = MultiRail::new(&conf)?;
        Ok(VtrainSim {
            model,
            cfg,
            policy,
            chunk_bytes,
            mr,
            sim_elems: 512,
            pool: BufferPool::new(),
        })
    }

    /// Congestion/retransmission penalty on a saturated 1 Gbps rail
    /// carrying ≥256 MB packets: grows with DP fan-in, only for
    /// single-rail runs (dual rails halve per-rail pressure below the
    /// collision regime).
    fn congestion_penalty(&self) -> f64 {
        match self.policy {
            Policy::SingleRail => 1.0 + 0.02 * self.cfg.dp as f64,
            _ => 1.0,
        }
    }

    /// Communication time for one iteration's DP allreduce (us).
    pub fn comm_us(&mut self) -> Result<f64> {
        let grad = self.cfg.dp_grad_bytes(self.model);
        let packets = if grad > 1024 * 1024 * 1024 {
            let n = grad.div_ceil(PACKET_SPLIT_BYTES);
            vec![PACKET_SPLIT_BYTES; n as usize]
        } else {
            vec![grad]
        };
        let mut total = 0.0;
        for bytes in packets {
            let mut buf = self
                .pool
                .acquire(self.mr.fab.nodes, self.sim_elems, |n, i| ((n * 31 + i) % 11) as f32);
            let elem_bytes = bytes as f64 / self.sim_elems as f64;
            // translate the modeled chunk size into real-buffer elements;
            // the replay pins the seed's fixed Ring/Ring_Chunked dispatch
            // (the paper's Fig. 18/19 algorithms), bypassing the planner
            self.mr.force_algo(Some(match self.chunk_bytes {
                None => Algo::Ring,
                Some(cb) => Algo::RingChunked {
                    chunk_elems: ((cb as f64 / elem_bytes).ceil() as usize).max(1),
                },
            }));
            let rep = self.mr.allreduce_scaled(&mut buf, elem_bytes)?;
            total += rep.total_us;
            self.mr.recycle(rep);
            self.pool.release(buf);
        }
        Ok(total * self.congestion_penalty())
    }

    /// Compute time for one iteration (us): virtual op-table replay.
    pub fn compute_us(&self) -> f64 {
        // per-DP-rank share of the global batch, pipelined over PP stages
        let samples = self.cfg.batch as f64 / self.cfg.dp as f64;
        let pipeline_eff = 0.85; // bubble overhead of PP=8 with microbatching
        samples * self.model.compute_us_per_sample() / pipeline_eff
    }

    /// Average per-node training iteration time (seconds), the Fig. 18/19
    /// metric.
    pub fn iteration_time_s(&mut self) -> Result<f64> {
        // warm the balancer's table first (paper: converges < 100 iters)
        for _ in 0..5 {
            self.comm_us()?;
        }
        let comm = self.comm_us()?;
        let compute = self.compute_us();
        // DP allreduce overlaps the tail of backprop only partially at
        // these payload sizes
        Ok((compute + comm) / 1e6)
    }
}

/// Supercomputer cluster with the IB NIC throttled to 1 Gbps (paper
/// §5.3.4) so both planes are 1 Gbps Ethernet-class.
fn throttled_supercomputer() -> ClusterSpec {
    let mut c = ClusterSpec::supercomputer();
    c.node.nics = vec![
        crate::net::rail::NicSpec::BCM5720,
        crate::net::rail::NicSpec::BCM5720,
    ];
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_configs() {
        let c = ParallelCfg::for_nodes(128);
        assert_eq!((c.tp, c.dp, c.pp, c.batch), (2, 16, 8, 512));
        assert_eq!(ParallelCfg::for_nodes(16).batch, 128);
    }

    #[test]
    fn grad_bytes_per_rank() {
        let c = ParallelCfg::for_nodes(64);
        // 2.7B * 4 / (2*8) = 675 MB
        assert_eq!(c.dp_grad_bytes(GptModel::Gpt2_7B), 675_000_000);
        assert!(c.dp_grad_bytes(GptModel::Gpt30B) > (1u64 << 30));
    }

    #[test]
    fn nezha_beats_gloo_at_scale() {
        let mut nezha =
            VtrainSim::new(GptModel::Gpt2_7B, 128, Policy::Nezha, None).unwrap();
        let mut gloo =
            VtrainSim::new(GptModel::Gpt2_7B, 128, Policy::SingleRail, None).unwrap();
        let tn = nezha.iteration_time_s().unwrap();
        let tg = gloo.iteration_time_s().unwrap();
        let ratio = tg / tn;
        assert!(
            ratio > 1.8 && ratio < 3.2,
            "expected ~2.36x (paper), got {ratio:.2} (nezha {tn:.1}s gloo {tg:.1}s)"
        );
    }

    #[test]
    fn iteration_time_grows_with_nodes() {
        let t16 = VtrainSim::new(GptModel::Gpt2_7B, 16, Policy::SingleRail, None)
            .unwrap()
            .iteration_time_s()
            .unwrap();
        let t128 = VtrainSim::new(GptModel::Gpt2_7B, 128, Policy::SingleRail, None)
            .unwrap()
            .iteration_time_s()
            .unwrap();
        assert!(t128 > t16, "t16 {t16} t128 {t128}");
    }

    #[test]
    fn chunked_helps_large_payloads() {
        let mut plain =
            VtrainSim::new(GptModel::Gpt2_7B, 64, Policy::Nezha, None).unwrap();
        let mut chunked =
            VtrainSim::new(GptModel::Gpt2_7B, 64, Policy::Nezha, Some(64 * 1024 * 1024))
                .unwrap();
        let tp = plain.iteration_time_s().unwrap();
        let tc = chunked.iteration_time_s().unwrap();
        assert!(tc <= tp * 1.05, "chunked {tc} plain {tp}");
    }
}
