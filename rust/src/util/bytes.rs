//! Byte-size formatting/parsing helpers ("64MB" <-> 67108864).

/// Format bytes with binary units, matching the paper's axis labels.
pub fn fmt_bytes(b: u64) -> String {
    const KB: u64 = 1024;
    const MB: u64 = 1024 * KB;
    const GB: u64 = 1024 * MB;
    if b >= GB && b % GB == 0 {
        format!("{}GB", b / GB)
    } else if b >= MB && b % MB == 0 {
        format!("{}MB", b / MB)
    } else if b >= KB && b % KB == 0 {
        format!("{}KB", b / KB)
    } else {
        format!("{b}B")
    }
}

/// Parse "64MB", "2kb", "512", "1GiB"-style sizes into bytes.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(p) = s.strip_suffix("gib").or(s.strip_suffix("gb")).or(s.strip_suffix("g")) {
        (p, 1u64 << 30)
    } else if let Some(p) = s.strip_suffix("mib").or(s.strip_suffix("mb")).or(s.strip_suffix("m")) {
        (p, 1u64 << 20)
    } else if let Some(p) = s.strip_suffix("kib").or(s.strip_suffix("kb")).or(s.strip_suffix("k")) {
        (p, 1u64 << 10)
    } else if let Some(p) = s.strip_suffix("b") {
        (p, 1)
    } else {
        (s.as_str(), 1)
    };
    num.trim().parse::<f64>().ok().map(|n| (n * mult as f64) as u64)
}

/// Format a microsecond latency with adaptive units.
pub fn fmt_us(us: f64) -> String {
    if us >= 1e6 {
        format!("{:.2}s", us / 1e6)
    } else if us >= 1e3 {
        format!("{:.1}ms", us / 1e3)
    } else {
        format!("{us:.1}us")
    }
}

/// GB/s throughput for `bytes` moved in `us` microseconds.
pub fn gbps(bytes: u64, us: f64) -> f64 {
    if us <= 0.0 {
        return 0.0;
    }
    bytes as f64 / us / 1e3 // bytes/us = MB/s => /1e3 = GB/s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        for s in [512u64, 1024, 2048, 1 << 20, 64 << 20, 1 << 30] {
            assert_eq!(parse_bytes(&fmt_bytes(s)), Some(s), "{s}");
        }
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_bytes("64MB"), Some(64 << 20));
        assert_eq!(parse_bytes("2kb"), Some(2048));
        assert_eq!(parse_bytes(" 512 "), Some(512));
        assert_eq!(parse_bytes("1.5k"), Some(1536));
        assert_eq!(parse_bytes("junk"), None);
    }

    #[test]
    fn units() {
        assert_eq!(fmt_bytes(2048), "2KB");
        assert_eq!(fmt_bytes(3 << 20), "3MB");
        assert_eq!(fmt_us(1500.0), "1.5ms");
        assert!((gbps(1 << 30, 1e6) - 1.073).abs() < 0.01);
    }
}
