//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `nezha <subcommand> [--flag value] [--switch] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, --key value options, bare switches and
/// positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    args.switches.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Parse a byte size option like `--size 64MB`.
    pub fn get_bytes(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(crate::util::bytes::parse_bytes)
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("bench --size 64MB --nodes 8 pos1 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("size"), Some("64MB"));
        assert_eq!(a.get_usize("nodes", 4), 8);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn eq_form() {
        let a = parse("run --lr=0.1 --steps=10");
        assert_eq!(a.get_f64("lr", 0.0), 0.1);
        assert_eq!(a.get_usize("steps", 0), 10);
    }

    #[test]
    fn byte_sizes() {
        let a = parse("x --size 2KB");
        assert_eq!(a.get_bytes("size", 0), 2048);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("x --flag");
        assert!(a.has("flag"));
        assert!(a.get("flag").is_none());
    }
}
