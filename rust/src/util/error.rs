//! Crate error type.
//!
//! Hand-rolled `Display`/`Error` impls (the offline registry does not carry
//! `thiserror`); the XLA variant only exists when the `pjrt` feature pulls
//! in the `xla` crate.

/// Unified error for coordinator, runtime and substrate failures.
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),

    #[cfg(feature = "pjrt")]
    Xla(xla::Error),

    Json { offset: usize, msg: String },

    Config(String),

    MissingArtifact(String),

    AllRailsDown(usize),

    /// A member network reported completion for a window the shared buffer
    /// never registered (stale handle after a failover migration/clear).
    UnregisteredWindow { offset: usize, len: usize },

    Topology(String),

    Msg(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla/pjrt error: {e}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::MissingArtifact(a) => {
                write!(f, "artifact `{a}` not found (run `make artifacts`)")
            }
            Error::AllRailsDown(r) => {
                write!(f, "rail {r} failed and no healthy rail remains")
            }
            Error::UnregisteredWindow { offset, len } => write!(
                f,
                "completing unregistered window [offset={offset}, len={len}] \
                 (migrated or cleared by a concurrent failover?)"
            ),
            Error::Topology(m) => write!(f, "topology error: {m}"),
            Error::Msg(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_stable() {
        assert_eq!(
            Error::AllRailsDown(3).to_string(),
            "rail 3 failed and no healthy rail remains"
        );
        assert_eq!(Error::Config("x".into()).to_string(), "config error: x");
        assert!(Error::UnregisteredWindow { offset: 8, len: 4 }
            .to_string()
            .contains("unregistered window [offset=8, len=4]"));
        assert_eq!(
            Error::MissingArtifact("m".into()).to_string(),
            "artifact `m` not found (run `make artifacts`)"
        );
        assert_eq!(
            Error::Json { offset: 4, msg: "bad".into() }.to_string(),
            "json parse error at byte 4: bad"
        );
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
