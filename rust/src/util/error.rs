//! Crate error type.

/// Unified error for coordinator, runtime and substrate failures.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla/pjrt error: {0}")]
    Xla(#[from] xla::Error),

    #[error("json parse error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    #[error("config error: {0}")]
    Config(String),

    #[error("artifact `{0}` not found (run `make artifacts`)")]
    MissingArtifact(String),

    #[error("rail {0} failed and no healthy rail remains")]
    AllRailsDown(usize),

    #[error("topology error: {0}")]
    Topology(String),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}
