//! Minimal JSON parser + emitter (serde is unavailable offline).
//!
//! Parses `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and emits metric/result JSON for the bench harness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::Error;
use crate::Result;

/// JSON value tree. Object keys are sorted (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // copy raw utf8 byte runs verbatim
                    let start = self.i - 1;
                    let mut end = self.i;
                    if c >= 0x80 {
                        while end < self.b.len() && self.b[end] >= 0x80 {
                            end += 1;
                        }
                        self.i = end;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"artifacts":[{"name":"a","inputs":[{"shape":[2,33],"dtype":"i32"}]}],"n":3.5,"ok":true,"none":null}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(
            j.get("artifacts").unwrap().as_arr().unwrap()[0]
                .get("name")
                .unwrap()
                .as_str(),
            Some("a")
        );
        assert_eq!(j.get("n").unwrap().as_f64(), Some(3.5));
        let shape = j.get("artifacts").unwrap().as_arr().unwrap()[0]
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![2, 33]);
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("x", Json::Num(1.0)),
            ("s", Json::Str("he\"llo\n".into())),
            ("a", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
    }

    #[test]
    fn whitespace_and_nesting() {
        let j = Json::parse(" { \"a\" : [ 1 , { \"b\" : [ ] } ] } ").unwrap();
        assert!(j.get("a").is_some());
    }
}
