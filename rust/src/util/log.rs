//! Leveled stderr logger with monotonic timestamps.
//!
//! Set verbosity with `NEZHA_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn init_from_env() {
    let lvl = match std::env::var("NEZHA_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    };
    set_level(lvl);
    let _ = START.get_or_init(Instant::now);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
