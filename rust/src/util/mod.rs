//! Support substrates built in-repo because the offline registry only
//! carries the `xla` closure: RNG, stats, JSON, CLI, tables, logging.

pub mod bytes;
pub mod cli;
pub mod error;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod table;
