//! Deterministic PRNG (PCG-XSH-RR 64/32 + splitmix seeding).
//!
//! The fabric simulation, fault injector and property tests all need a
//! seedable, reproducible RNG; `rand` is not available offline, so this is
//! a minimal PCG implementation with the handful of distributions we use.

/// PCG-XSH-RR 64/32: small, fast, statistically solid for simulation use.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed via splitmix64 so similar seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let mut rng = Pcg { state: 0, inc: next() | 1 };
        rng.state = next();
        rng.next_u32();
        rng
    }

    /// Independent substream `i` of this generator (stream splitting).
    pub fn split(&mut self, i: u64) -> Pcg {
        Pcg::new(self.next_u64() ^ i.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Deterministic per-(rail, op-epoch) stream: the fabric's parallel
    /// executor gives every rail its own generator derived purely from
    /// `(seed, rail, epoch)`, so concurrent rails draw independent
    /// sequences whose values do not depend on cross-rail execution order
    /// — serial and parallel execution sample identical modeled times.
    /// The three inputs are whitened through distinct odd multipliers
    /// before the splitmix seeding, so neighbouring rails/epochs land in
    /// unrelated streams.
    pub fn for_stream(seed: u64, rail: u64, epoch: u64) -> Pcg {
        Pcg::new(
            seed.wrapping_mul(0xD1B54A32D192ED03)
                ^ rail.wrapping_mul(0xA24BAED4963EE407).rotate_left(17)
                ^ epoch.wrapping_mul(0x9FB21C651E98DF25).rotate_left(41),
        )
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire rejection-free is overkill here).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 { 0 } else { self.next_u64() % n }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal multiplicative jitter with multiplier sigma; mean ~1.
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Fill `out` with log-normal jitter multipliers in one batched pass —
    /// the fabric's per-round sampling path draws all of a lockstep
    /// round's per-node multipliers through this instead of one call per
    /// message. The draw sequence is identical to repeated [`Pcg::jitter`]
    /// calls, so batching preserves reproducibility.
    pub fn fill_jitter(&mut self, sigma: f64, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.jitter(sigma);
        }
    }

    /// Fill a slice with N(0, scale) f32 values (synthetic gradients).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * scale;
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(7);
        let mut b = Pcg::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(4);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn fill_jitter_matches_sequential_draws() {
        let mut a = Pcg::new(11);
        let mut b = Pcg::new(11);
        let mut batch = [0.0f64; 16];
        a.fill_jitter(0.3, &mut batch);
        for (i, &v) in batch.iter().enumerate() {
            assert_eq!(v, b.jitter(0.3), "draw {i}");
        }
    }

    #[test]
    fn stream_derivation_deterministic_and_independent() {
        let seq = |seed, rail, epoch| {
            let mut r = Pcg::for_stream(seed, rail, epoch);
            (0..8).map(|_| r.next_u64()).collect::<Vec<_>>()
        };
        // pure function of (seed, rail, epoch)
        assert_eq!(seq(42, 0, 1), seq(42, 0, 1));
        // any coordinate change moves to an unrelated stream
        assert_ne!(seq(42, 0, 1), seq(42, 1, 1));
        assert_ne!(seq(42, 0, 1), seq(42, 0, 2));
        assert_ne!(seq(42, 0, 1), seq(43, 0, 1));
        // rail/epoch must not alias (rail 1, epoch 0) vs (rail 0, epoch 1)
        assert_ne!(seq(7, 1, 0), seq(7, 0, 1));
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg::new(6);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
