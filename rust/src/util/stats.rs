//! Streaming statistics: Welford accumulator, percentiles, EMA, histograms.
//!
//! Used by the Timer (per-size allreduce cost tracking), the bench harness
//! and the metric reports.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exponential moving average with configurable smoothing.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn add(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Percentile of a sample set (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
    s[rank.min(s.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Power-of-two bucketed histogram keyed by byte size — mirrors the paper's
/// Fig. 15 (allreduce count & size per epoch).
#[derive(Debug, Clone, Default)]
pub struct SizeHistogram {
    /// bucket index = floor(log2(bytes)); value = (count, total_bytes)
    buckets: std::collections::BTreeMap<u32, (u64, u64)>,
}

impl SizeHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, bytes: u64) {
        let b = 63 - bytes.max(1).leading_zeros();
        let e = self.buckets.entry(b).or_insert((0, 0));
        e.0 += 1;
        e.1 += bytes;
    }

    /// (bucket_lower_bound_bytes, count, total_bytes) rows, ascending.
    pub fn rows(&self) -> Vec<(u64, u64, u64)> {
        self.buckets.iter().map(|(&b, &(c, t))| (1u64 << b, c, t)).collect()
    }

    pub fn total_count(&self) -> u64 {
        self.buckets.values().map(|v| v.0).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.buckets.values().map(|v| v.1).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let naive_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.var() - naive_var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..64 {
            e.add(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_basic() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p50 = percentile(&xs, 50.0);
        assert!((49.0..=51.0).contains(&p50));
    }

    #[test]
    fn histogram_buckets() {
        let mut h = SizeHistogram::new();
        h.add(1024);
        h.add(1500);
        h.add(4096);
        let rows = h.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], (1024, 2, 2524));
        assert_eq!(rows[1], (4096, 1, 4096));
        assert_eq!(h.total_count(), 3);
    }
}
