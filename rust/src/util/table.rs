//! Aligned plain-text tables for bench output (paper-style rows).

/// Column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let empty = String::new();
                let c = cells.get(i).unwrap_or(&empty);
                line.push_str(&format!("{c:>w$}  ", w = w));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            out.push('\n');
            out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Convenience: format f64 with fixed decimals.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned() {
        let mut t = Table::new(&["size", "lat(us)"]);
        t.row(vec!["1KB".into(), "9".into()]);
        t.row(vec!["64MB".into(), "181484".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("size"));
        assert!(lines[3].contains("181484"));
        // columns right-aligned to same width
        assert_eq!(lines[2].find("1KB").is_some(), true);
    }

    #[test]
    fn ragged_rows_ok() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into(), "2".into()]);
        assert!(t.render().contains('2'));
    }
}
