//! Tier-1 smoke test for the tracked hot-path benchmark: quick mode runs
//! end-to-end and emits well-formed JSON at the repo root. Record, don't
//! gate — no wall-clock thresholds here (machine speed varies); CI only
//! uploads the artifact, and regenerating the file on every verified run
//! keeps the checked-in trajectory honest.

use nezha::bench::hotpath;
use nezha::util::json::Json;

#[test]
fn hotpath_bench_quick_mode_emits_wellformed_json() {
    let doc = hotpath::write_report(true).unwrap();

    // the artifact on disk must parse back to exactly the same document
    let text = std::fs::read_to_string(hotpath::report_path()).unwrap();
    let parsed = Json::parse(&text).unwrap();
    assert_eq!(parsed, doc);

    assert_eq!(parsed.get("bench").unwrap().as_str(), Some("hotpath"));
    assert_eq!(parsed.get("mode").unwrap().as_str(), Some("quick"));
    let sweep = parsed.get("sweep").unwrap().as_arr().unwrap();
    assert_eq!(sweep.len(), hotpath::HOTPATH_SIZES.len());
    for (row, &bytes) in sweep.iter().zip(&hotpath::HOTPATH_SIZES) {
        assert_eq!(row.get("bytes").unwrap().as_f64(), Some(bytes as f64));
        let before = row.get("before_ops_per_sec").unwrap().as_f64().unwrap();
        let after = row.get("after_ops_per_sec").unwrap().as_f64().unwrap();
        let speedup = row.get("speedup").unwrap().as_f64().unwrap();
        assert!(before > 0.0 && after > 0.0, "throughputs must be positive");
        assert!(
            (speedup - after / before).abs() < 1e-9,
            "speedup field inconsistent with the recorded throughputs"
        );
    }
    assert!(parsed.get("min_speedup").unwrap().as_f64().unwrap() > 0.0);

    // serial-vs-parallel executor sweep (physical payloads; record, don't
    // gate the ratio here — machine core counts vary)
    let exec = parsed.get("exec").unwrap();
    let exec_sweep = exec.get("sweep").unwrap().as_arr().unwrap();
    assert_eq!(exec_sweep.len(), hotpath::exec_sizes(true).len());
    for (row, &bytes) in exec_sweep.iter().zip(hotpath::exec_sizes(true)) {
        assert_eq!(row.get("bytes").unwrap().as_f64(), Some(bytes as f64));
        let serial = row.get("serial_ops_per_sec").unwrap().as_f64().unwrap();
        let parallel = row.get("parallel_ops_per_sec").unwrap().as_f64().unwrap();
        let speedup = row.get("speedup").unwrap().as_f64().unwrap();
        assert!(serial > 0.0 && parallel > 0.0, "exec throughputs must be positive");
        assert!(
            (speedup - parallel / serial).abs() < 1e-9,
            "exec speedup field inconsistent with the recorded throughputs"
        );
    }
    assert!(exec.get("min_speedup").unwrap().as_f64().unwrap() > 0.0);

    let kernels = parsed.get("kernels").unwrap();
    assert!(kernels.get("add_into_gbps").unwrap().as_f64().unwrap() > 0.0);
    assert!(kernels.get("reduce_copy_gbps").unwrap().as_f64().unwrap() > 0.0);
    // the 8/16/32-lane width sweep behind the shipped KERNEL_LANES
    let lanes = kernels.get("lanes").unwrap().as_f64().unwrap() as usize;
    let widths = kernels.get("width_sweep").unwrap().as_arr().unwrap();
    assert_eq!(widths.len(), 3);
    let mut seen = Vec::new();
    for w in widths {
        let l = w.get("lanes").unwrap().as_f64().unwrap() as usize;
        assert!(w.get("add_into_gbps").unwrap().as_f64().unwrap() > 0.0);
        assert!(w.get("reduce_copy_gbps").unwrap().as_f64().unwrap() > 0.0);
        seen.push(l);
    }
    assert_eq!(seen, vec![8, 16, 32]);
    assert!(seen.contains(&lanes), "shipped width must be in the sweep");

    // bench_allreduce-style policy-sim wall-clock rides in the same
    // trajectory (record, don't gate)
    let sim = parsed.get("policy_sim").unwrap();
    assert!(sim.get("wall_seconds").unwrap().as_f64().unwrap() > 0.0);
    assert!(sim.get("modeled_ops").unwrap().as_f64().unwrap() > 0.0);
    assert!(sim.get("ops_per_sec").unwrap().as_f64().unwrap() > 0.0);

    // data-plane integrity: checksum kernel bandwidth + clean-path cost
    // of the send/verify passes (record, don't gate)
    let integrity = parsed.get("integrity").unwrap();
    assert!(integrity.get("checksum_gbps").unwrap().as_f64().unwrap() > 0.0);
    let on = integrity.get("clean_on_ops_per_sec").unwrap().as_f64().unwrap();
    let off = integrity.get("clean_off_ops_per_sec").unwrap().as_f64().unwrap();
    let pct = integrity.get("clean_overhead_pct").unwrap().as_f64().unwrap();
    assert!(on > 0.0 && off > 0.0, "integrity throughputs must be positive");
    assert!(
        (pct - (off / on - 1.0) * 100.0).abs() < 1e-9,
        "overhead field inconsistent with the recorded throughputs"
    );

    // barrier-free scheduler: modeled barrier vs priority iteration time
    // per model. The times are deterministic modeled quantities, so here
    // (unlike the wall-clock sections) the invariants ARE gated:
    // bit-identical gradients, real overlap, drained queue, priority wins.
    let scheduler = parsed.get("scheduler").unwrap();
    assert_eq!(
        scheduler.get("all_bit_identical").unwrap(),
        &Json::Bool(true),
        "priority gradients must match barrier bit-for-bit"
    );
    assert_eq!(
        scheduler.get("all_improved").unwrap(),
        &Json::Bool(true),
        "priority must beat barrier on the comm-bound paper models"
    );
    let sched_rows = scheduler.get("sweep").unwrap().as_arr().unwrap();
    assert_eq!(sched_rows.len(), hotpath::SCHED_MODELS.len());
    for (row, &(model, batch)) in sched_rows.iter().zip(&hotpath::SCHED_MODELS) {
        assert_eq!(row.get("model").unwrap().as_str(), Some(model));
        assert_eq!(row.get("batch_per_gpu").unwrap().as_f64(), Some(batch as f64));
        let bt = row.get("barrier_iter_us").unwrap().as_f64().unwrap();
        let pt = row.get("priority_iter_us").unwrap().as_f64().unwrap();
        let speedup = row.get("speedup").unwrap().as_f64().unwrap();
        assert!(bt > 0.0 && pt > 0.0, "iteration times must be positive");
        assert!(
            (speedup - bt / pt).abs() < 1e-9,
            "scheduler speedup field inconsistent with the recorded times"
        );
        assert_eq!(row.get("bit_identical").unwrap(), &Json::Bool(true));
        assert!(
            row.get("boundary_in_flight_max").unwrap().as_f64().unwrap() >= 1.0,
            "{model}: at least one op must be in flight across an iteration boundary"
        );
        assert_eq!(row.get("queue_drained").unwrap(), &Json::Bool(true));
    }

    // multi-tenant arbiter sweep: solo vs 2-job vs 4-job aggregate
    // ops/sec (record, don't gate)
    let tenancy = parsed.get("tenancy").unwrap();
    let rows = tenancy.get("sweep").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), hotpath::TENANCY_JOBS.len());
    for (row, &jobs) in rows.iter().zip(&hotpath::TENANCY_JOBS) {
        assert_eq!(row.get("jobs").unwrap().as_f64(), Some(jobs as f64));
        assert!(
            row.get("aggregate_ops_per_sec").unwrap().as_f64().unwrap() > 0.0,
            "tenancy throughput must be positive"
        );
    }
}
