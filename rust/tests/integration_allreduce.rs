//! Integration: multi-rail allreduce correctness and performance shape
//! across policies, combos and node counts (real f32 payloads).

use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::collective::Algo;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::topology::parse_combo;
use nezha::util::rng::Pcg;

fn cfg(combo: &str, nodes: usize, policy: Policy) -> Config {
    Config {
        nodes,
        combo: parse_combo(combo).unwrap(),
        policy,
        deterministic: true,
        ..Config::default()
    }
}

fn random_buf(rng: &mut Pcg, nodes: usize, len: usize) -> (UnboundBuffer, Vec<f32>) {
    let data: Vec<Vec<f32>> = (0..nodes)
        .map(|_| (0..len).map(|_| (rng.range(-8, 8) as f32) * 0.25).collect())
        .collect();
    let expect: Vec<f32> = (0..len)
        .map(|i| data.iter().map(|d| d[i]).sum())
        .collect();
    (UnboundBuffer::new(data), expect)
}

fn check(buf: &UnboundBuffer, expect: &[f32]) {
    for n in 0..buf.nodes() {
        for (i, e) in expect.iter().enumerate() {
            let got = buf.node(n)[i];
            assert!(
                (got - e).abs() < 1e-4,
                "node {n} elem {i}: {got} vs {e}"
            );
        }
    }
}

#[test]
fn every_policy_every_combo_is_correct() {
    let mut rng = Pcg::new(11);
    for combo in ["tcp-tcp", "tcp-sharp", "tcp-glex"] {
        for policy in [Policy::Nezha, Policy::Mrib, Policy::Mptcp, Policy::SingleRail] {
            for nodes in [2usize, 4] {
                let mut mr = MultiRail::new(&cfg(combo, nodes, policy)).unwrap();
                for len in [100usize, 70_000] {
                    let (mut buf, expect) = random_buf(&mut rng, nodes, len);
                    mr.allreduce(&mut buf).unwrap();
                    check(&buf, &expect);
                }
            }
        }
    }
}

#[test]
fn ring_chunked_is_correct_and_counts_match() {
    let mut rng = Pcg::new(12);
    let mut mr = MultiRail::new(&cfg("tcp-tcp", 4, Policy::Nezha))
        .unwrap()
        .with_algo(Algo::RingChunked { chunk_elems: 4096 });
    let (mut buf, expect) = random_buf(&mut rng, 4, 100_000);
    let rep = mr.allreduce(&mut buf).unwrap();
    check(&buf, &expect);
    assert!(rep.total_us > 0.0);
}

#[test]
fn repeated_ops_deterministic_under_fixed_seed() {
    let run = || {
        let mut mr = MultiRail::new(&cfg("tcp-sharp", 4, Policy::Nezha)).unwrap();
        let mut out = Vec::new();
        for i in 0..20 {
            let mut buf = UnboundBuffer::from_fn(4, 4096, |n, j| ((n + j + i) % 7) as f32);
            out.push(mr.allreduce(&mut buf).unwrap().total_us);
        }
        out
    };
    assert_eq!(run(), run());
}

#[test]
fn heterogeneous_large_payloads_beat_best_single_rail() {
    // 16MB payloads: Nezha TCP-SHARP must beat SHARP alone (paper Fig. 10)
    let measure = |combo: &str, policy: Policy| -> f64 {
        let mut mr = MultiRail::new(&cfg(combo, 8, policy)).unwrap();
        let mut total = 0.0;
        for i in 0..40 {
            let mut buf = UnboundBuffer::from_fn(8, 1024, |n, j| ((n + j) % 5) as f32);
            let t = mr.allreduce_scaled(&mut buf, 16384.0).unwrap().total_us;
            if i >= 30 {
                total += t;
            }
        }
        total / 10.0
    };
    let sharp = measure("sharp", Policy::SingleRail);
    let nezha = measure("tcp-sharp", Policy::Nezha);
    assert!(
        nezha < sharp,
        "multi-rail {nezha} should beat single SHARP {sharp} at 16MB"
    );
}

#[test]
fn small_heterogeneous_payloads_do_not_regress_to_tcp() {
    // 4KB: MRIB/MPTCP degrade toward TCP latency; Nezha stays RDMA-class
    let one = |policy: Policy| -> f64 {
        let mut mr = MultiRail::new(&cfg("tcp-sharp", 4, policy)).unwrap();
        let mut t = 0.0;
        for _ in 0..5 {
            let mut buf = UnboundBuffer::from_fn(4, 1024, |n, j| ((n + j) % 5) as f32);
            t = mr.allreduce_scaled(&mut buf, 4.0).unwrap().total_us;
        }
        t
    };
    let nezha = one(Policy::Nezha);
    let mrib = one(Policy::Mrib);
    assert!(nezha < 200.0, "Nezha 4KB hetero latency {nezha}us");
    assert!(mrib > 900.0, "MRIB should pay the TCP straggler: {mrib}us");
}

#[test]
fn mptcp_pays_slicing_overhead_at_scale() {
    let one = |policy: Policy| -> f64 {
        let mut mr = MultiRail::new(&cfg("tcp-tcp", 4, policy)).unwrap();
        let mut t = 0.0;
        for i in 0..35 {
            let mut buf = UnboundBuffer::from_fn(4, 1024, |n, j| ((n + j) % 5) as f32);
            let r = mr.allreduce_scaled(&mut buf, 65536.0).unwrap().total_us; // 64MB
            if i >= 30 {
                t = r;
            }
        }
        t
    };
    let nezha = one(Policy::Nezha);
    let mptcp = one(Policy::Mptcp);
    // paper Table 1 / §4.3: slicing adds 18-27%
    assert!(
        mptcp > nezha * 1.1 && mptcp < nezha * 1.6,
        "mptcp {mptcp} vs nezha {nezha}"
    );
}

#[test]
fn throughput_report_consistent() {
    let mut mr = MultiRail::new(&cfg("tcp-tcp", 4, Policy::Nezha)).unwrap();
    let mut buf = UnboundBuffer::from_fn(4, 1 << 20, |n, j| ((n + j) % 5) as f32);
    let rep = mr.allreduce(&mut buf).unwrap();
    assert_eq!(rep.bytes, 4 << 20);
    let sum_rail: u64 = rep.per_rail.iter().map(|s| s.bytes).sum();
    assert_eq!(sum_rail, rep.bytes, "rail shares must cover the payload");
    assert!(rep.throughput_gbps() > 0.0);
}
