//! Integration: the multi-tenant fabric arbiter — the tenancy matrix
//! {1, 2, 4 jobs} × {fair-share, strict-priority} × {serial, parallel}
//! with per-job numerics bit-identical to solo in every cell, absence of
//! priority inversion under strict priority (latency-class p99 bounded
//! under scavenger load, while 4-way fair-share provably is not),
//! replan-on-churn within the paper's 200 ms recovery budget, and
//! seeded-fuzzer properties over the grant ledger (conservation and
//! determinism).

use nezha::config::{Config, Policy};
use nezha::coordinator::arbiter::{
    ArbiterMode, FabricArbiter, GrantLedger, JobId, JobSpec, PriorityClass,
};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::control::exception::PAPER_RECOVERY_BUDGET_US;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::cpu_pool::ExecMode;
use nezha::net::protocol::ProtoKind;
use nezha::util::rng::Pcg;

const NODES: usize = 4;
const LEN: usize = 4096;
/// Steps per cell after the explicit numerics op (p99 = max over 1+OPS).
const OPS: usize = 4;
const CASES: usize = 60;

fn cfg(rails: usize, exec: ExecMode) -> Config {
    Config {
        nodes: NODES,
        combo: vec![ProtoKind::Tcp; rails],
        policy: Policy::Nezha,
        deterministic: true,
        exec,
        ..Config::default()
    }
}

fn tenant(rails: usize, exec: ExecMode) -> MultiRail {
    MultiRail::new(&cfg(rails, exec)).unwrap()
}

fn fill(salt: usize) -> impl Fn(usize, usize) -> f32 + Copy {
    move |n: usize, i: usize| ((n * 7 + i * 3 + salt) % 13) as f32
}

/// The cell's tenant mix: job 0 is the latency-class foreground (4 MB
/// collectives); the rest are scavenger bulk (8 MB).
fn mix(n: usize) -> Vec<JobSpec> {
    let mut v = vec![JobSpec::new("fg", PriorityClass::Latency).payload(4 << 20)];
    for k in 1..n {
        v.push(JobSpec::new(&format!("bg{k}"), PriorityClass::Scavenger).payload(8 << 20));
    }
    v
}

/// Run one cell: admit the mix, do the explicit per-job numerics op
/// (checked bitwise against a solo coordinator), then `OPS` sustained
/// windows. Returns (p99 of the latency job, per-job latency vectors).
fn run_cell(n: usize, mode: ArbiterMode, exec: ExecMode) -> (f64, Vec<Vec<f64>>) {
    let tag = format!("{n}-job/{}/{exec:?}", mode.name());
    let mut arb = FabricArbiter::new(mode, 2);
    let ids: Vec<JobId> =
        mix(n).into_iter().map(|s| arb.admit(s, NODES, tenant(2, exec))).collect();
    for (k, &id) in ids.iter().enumerate() {
        let payload = arb.job(id).unwrap().spec.payload_bytes as f64;
        let elem_bytes = payload / LEN as f64;
        let mut buf = UnboundBuffer::from_fn(NODES, LEN, fill(k));
        let mut solo_buf = UnboundBuffer::from_fn(NODES, LEN, fill(k));
        arb.run_op_scaled(id, &mut buf, elem_bytes).unwrap();
        // identical op on a pristine solo coordinator: contention may
        // only scale modeled time, never touch payload bits
        let mut solo = tenant(2, exec);
        solo.allreduce_scaled(&mut solo_buf, elem_bytes).unwrap();
        for node in 0..NODES {
            for i in 0..LEN {
                assert_eq!(
                    buf.node(node)[i].to_bits(),
                    solo_buf.node(node)[i].to_bits(),
                    "{tag}: job {k} node {node} elem {i} diverged from solo"
                );
            }
        }
    }
    for _ in 0..OPS {
        arb.step().unwrap();
    }
    // conservation in every cell
    for rail in 0..2 {
        let sum = arb.ledger().rail_sum(rail);
        assert!(sum <= 1.0 + 1e-9, "{tag}: rail {rail} oversubscribed ({sum})");
        assert!((sum - 1.0).abs() <= 1e-9, "{tag}: shared rail {rail} undersubscribed ({sum})");
    }
    let p99 = arb.p99_us(ids[0]).unwrap();
    let lats: Vec<Vec<f64>> =
        ids.iter().map(|&id| arb.job(id).unwrap().latencies_us.clone()).collect();
    (p99, lats)
}

/// The tenancy matrix. Within each (jobs, mode) pair the serial and
/// parallel executors must agree bit-for-bit on every tenant's modeled
/// latency sequence; strict priority must keep the latency-class p99
/// within 2× solo in every cell, and 4-way fair-share must provably
/// break that bound (the priority-inversion case the arbiter exists to
/// prevent).
#[test]
fn tenancy_matrix_numerics_latency_and_executor_identity() {
    // solo baseline: same op structure as a 1-job cell
    let (p99_solo, solo_lats) = run_cell(1, ArbiterMode::FairShare, ExecMode::Serial);
    assert!(p99_solo > 0.0);

    for &n in &[1usize, 2, 4] {
        for &mode in &[ArbiterMode::FairShare, ArbiterMode::StrictPriority] {
            let (p99_s, lats_s) = run_cell(n, mode, ExecMode::Serial);
            let (p99_p, lats_p) = run_cell(n, mode, ExecMode::Parallel);
            let tag = format!("{n}-job/{}", mode.name());

            // serial vs parallel: bit-identical modeled latencies per job
            assert_eq!(lats_s.len(), lats_p.len(), "{tag}: job count");
            for (j, (a, b)) in lats_s.iter().zip(&lats_p).enumerate() {
                let ab: Vec<u64> = a.iter().map(|t| t.to_bits()).collect();
                let bb: Vec<u64> = b.iter().map(|t| t.to_bits()).collect();
                assert_eq!(ab, bb, "{tag}: job {j} serial/parallel latencies diverge");
            }
            assert_eq!(p99_s.to_bits(), p99_p.to_bits(), "{tag}: p99 diverges across executors");

            // 1-job cells ARE solo: latencies bit-identical to the baseline
            if n == 1 {
                let ab: Vec<u64> = lats_s[0].iter().map(|t| t.to_bits()).collect();
                let sb: Vec<u64> = solo_lats[0].iter().map(|t| t.to_bits()).collect();
                assert_eq!(ab, sb, "{tag}: solo cell latencies differ from baseline");
            }

            match mode {
                // no priority inversion: scavenger bulk never drags the
                // latency class past 2x solo
                ArbiterMode::StrictPriority => assert!(
                    p99_s <= 2.0 * p99_solo,
                    "{tag}: latency p99 {p99_s} breaches 2x solo {p99_solo}"
                ),
                // fair-share at 4 tenants must breach the bound — this is
                // exactly the inversion strict priority prevents
                ArbiterMode::FairShare if n == 4 => assert!(
                    p99_s > 2.0 * p99_solo,
                    "{tag}: expected 4-way fair-share to exceed 2x solo \
                     ({p99_s} vs {p99_solo})"
                ),
                ArbiterMode::FairShare => {}
            }
        }
    }
}

/// Churn: arrivals squeeze the incumbent at the next window boundary,
/// departures restore solo grants, every replan stays inside the paper's
/// recovery budget, and post-restore modeled latencies return to solo
/// bit-exactly (contended predictions match contended measurements, so
/// no correction residue survives the restore).
#[test]
fn churn_replans_within_budget_and_restores_solo_times() {
    let mut arb = FabricArbiter::new(ArbiterMode::FairShare, 1);
    let fg = arb.admit(
        JobSpec::new("fg", PriorityClass::Standard).payload(4 << 20),
        NODES,
        tenant(1, ExecMode::Serial),
    );
    for _ in 0..3 {
        arb.step().unwrap();
    }
    let t_solo = *arb.job(fg).unwrap().latencies_us.last().unwrap();

    let bg1 = arb.admit(
        JobSpec::new("bg1", PriorityClass::Scavenger).payload(8 << 20),
        NODES,
        tenant(1, ExecMode::Serial),
    );
    let bg2 = arb.admit(
        JobSpec::new("bg2", PriorityClass::Scavenger).payload(8 << 20),
        NODES,
        tenant(1, ExecMode::Serial),
    );
    for _ in 0..2 {
        arb.step().unwrap();
    }
    let t_contended = *arb.job(fg).unwrap().latencies_us.last().unwrap();
    assert!(
        t_contended > 1.5 * t_solo,
        "1/3 grant should slow the incumbent well past solo: {t_solo} -> {t_contended}"
    );

    let gone = arb.depart(bg1).unwrap();
    assert_eq!(gone.mr.rail_grant(0), 1.0, "departing tenant must leave with solo grants");
    arb.depart(bg2).unwrap();
    assert_eq!(arb.job(fg).unwrap().mr.rail_grant(0), 1.0);
    arb.step().unwrap();
    let t_restored = *arb.job(fg).unwrap().latencies_us.last().unwrap();
    assert_eq!(
        t_restored.to_bits(),
        t_solo.to_bits(),
        "restored grant must reproduce solo modeled time bit-exactly \
         ({t_solo} vs {t_restored})"
    );

    // churn ledger: 5 events (3 admits, 2 departs), each inside budget
    assert_eq!(arb.churn().len(), 5);
    assert!(arb.all_churn_within(PAPER_RECOVERY_BUDGET_US));
    // the solo admission replanned nobody; every later event replanned
    // at least the incumbent
    assert_eq!(arb.churn()[0].jobs_replanned, 0);
    for ev in &arb.churn()[1..] {
        assert!(ev.jobs_replanned >= 1, "churn event {ev:?} replanned nobody");
        assert!(ev.replan_us > 0.0 && ev.replan_us < PAPER_RECOVERY_BUDGET_US);
    }
}

fn random_jobs(rng: &mut Pcg, n_rails: usize) -> Vec<(JobId, JobSpec)> {
    let n_jobs = 1 + rng.below(6) as usize;
    (0..n_jobs)
        .map(|k| {
            let class = match rng.below(3) {
                0 => PriorityClass::Latency,
                1 => PriorityClass::Standard,
                _ => PriorityClass::Scavenger,
            };
            let spec = JobSpec::new(&format!("j{k}"), class)
                .weight(0.1 + rng.f64() * 4.0)
                .rails(1 + rng.below((1u64 << n_rails) - 1));
            (JobId(k as u64), spec)
        })
        .collect()
}

/// Property: for random tenant sets, grants on every rail are positive,
/// individually ≤ 1, sum to exactly 1 on any rail with an eligible
/// tenant, and to 0 on empty rails — in both arbiter modes.
#[test]
fn prop_grants_conserve_bandwidth_per_rail() {
    let mut rng = Pcg::new(6001);
    for case in 0..CASES {
        let n_rails = 1 + rng.below(3) as usize;
        let owned = random_jobs(&mut rng, n_rails);
        let refs: Vec<(JobId, &JobSpec)> = owned.iter().map(|(id, s)| (*id, s)).collect();
        for mode in [ArbiterMode::FairShare, ArbiterMode::StrictPriority] {
            let mut l = GrantLedger::new(n_rails);
            l.recompute(mode, &refs);
            for rail in 0..n_rails {
                let sum = l.rail_sum(rail);
                assert!(sum <= 1.0 + 1e-9, "case {case} {mode:?} rail {rail}: sum {sum} > 1");
                let eligible = owned.iter().any(|(_, s)| s.admits(rail));
                if eligible {
                    assert!(
                        (sum - 1.0).abs() <= 1e-9,
                        "case {case} {mode:?} rail {rail}: not fully subscribed ({sum})"
                    );
                } else {
                    assert_eq!(sum, 0.0, "case {case}: empty rail granted bandwidth");
                }
                for (id, s) in &owned {
                    match (s.admits(rail), l.grant(rail, *id)) {
                        (true, Some(g)) => assert!(
                            g > 0.0 && g <= 1.0 + 1e-9,
                            "case {case} {mode:?} rail {rail} job {id:?}: grant {g}"
                        ),
                        (false, None) => {}
                        (admits, g) => panic!(
                            "case {case} {mode:?} rail {rail} job {id:?}: \
                             admits={admits} grant={g:?}"
                        ),
                    }
                }
            }
        }
    }
}

/// Property: grant recomputation is a pure function of the tenant set —
/// fresh ledgers and repeated recomputes agree bit-for-bit.
#[test]
fn prop_grant_recompute_deterministic() {
    let mut rng = Pcg::new(6002);
    for case in 0..CASES {
        let n_rails = 1 + rng.below(3) as usize;
        let owned = random_jobs(&mut rng, n_rails);
        let refs: Vec<(JobId, &JobSpec)> = owned.iter().map(|(id, s)| (*id, s)).collect();
        for mode in [ArbiterMode::FairShare, ArbiterMode::StrictPriority] {
            let mut a = GrantLedger::new(n_rails);
            a.recompute(mode, &refs);
            let mut b = GrantLedger::new(n_rails);
            b.recompute(mode, &refs);
            // repeated recompute on a dirty ledger must also converge
            b.recompute(mode, &refs);
            for rail in 0..n_rails {
                for (id, _) in &owned {
                    assert_eq!(
                        a.grant(rail, *id).map(f64::to_bits),
                        b.grant(rail, *id).map(f64::to_bits),
                        "case {case} {mode:?} rail {rail} job {id:?}: nondeterministic grant"
                    );
                }
            }
            assert_eq!(a.preempted(), b.preempted(), "case {case} {mode:?}: preemption set");
        }
    }
}
