//! Integration: elastic membership (node join/leave self-recovery).
//!
//! The churn matrix — {node leave, node rejoin, rack leave,
//! leave-during-op} × {flat, racked-pods} × {serial, parallel} — must
//! recover inside the paper's 200 ms budget at p99, invalidate cached
//! plans through the membership epoch, and keep numerics bit-exact: the
//! surviving set reduces exactly like a fresh run at the survivor count,
//! and a rejoined cluster exactly like one that never lost the node.

use nezha::config::{Config, Policy};
use nezha::coordinator::arbiter::job::percentile;
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::control::exception::PAPER_RECOVERY_BUDGET_US;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::cpu_pool::ExecMode;
use nezha::net::fault::MembershipSchedule;
use nezha::net::topology::{parse_combo, ClusterSpec};

const LEN: usize = 1 << 12;
/// Modeled 8MB ops on small real buffers.
const ELEM_BYTES: f64 = (8 << 20) as f64 / LEN as f64;

fn flat(nodes: usize, exec: ExecMode) -> Config {
    let mut c = Config {
        nodes,
        combo: parse_combo("tcp-tcp").unwrap(),
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    c.exec = exec;
    c
}

fn racked(exec: ExecMode) -> Config {
    let mut c = flat(32, exec);
    c.cluster = ClusterSpec::racked_pods(4, 16);
    c
}

fn make(nodes: usize, len: usize) -> UnboundBuffer {
    UnboundBuffer::from_fn(nodes, len, |n, i| ((n + 1) * (i % 13 + 1)) as f32)
}

fn reduced_ok(buf: &UnboundBuffer, nodes: usize, len: usize) {
    for n in 0..buf.nodes() {
        for i in (0..len).step_by(499) {
            let expect: f32 = (0..nodes).map(|m| ((m + 1) * (i % 13 + 1)) as f32).sum();
            assert_eq!(buf.node(n)[i], expect, "node {n} elem {i}");
        }
    }
}

fn op(mr: &mut MultiRail, nodes: usize) {
    let mut buf = make(nodes, LEN);
    mr.allreduce_scaled(&mut buf, ELEM_BYTES).unwrap();
    reduced_ok(&buf, nodes, LEN);
}

/// Drive every churn scenario over one cluster shape and collect the
/// charged recovery times.
fn churn_scenarios(cfg: &Config, leave_node: usize, rack: &[usize], samples: &mut Vec<f64>) {
    let nodes = cfg.nodes;

    // -- single node leave mid-training --
    let mut mr = MultiRail::new(cfg).unwrap();
    op(&mut mr, nodes);
    let e_plan = mr.plan_epoch();
    let rec = mr.node_leave(leave_node).unwrap();
    assert!(!rec.rejoin);
    assert_eq!(rec.count, 1);
    assert_eq!(rec.epoch, 1);
    assert_eq!(mr.membership_epoch(), 1);
    assert_eq!(mr.active_nodes(), nodes - 1);
    op(&mut mr, nodes - 1);
    assert!(mr.plan_epoch() > e_plan, "leave must force a replan");
    samples.push(rec.recovery_us);

    // -- leave then rejoin: full round-trip back to the home topology --
    let mut mr = MultiRail::new(cfg).unwrap();
    op(&mut mr, nodes);
    let l = mr.node_leave(leave_node).unwrap();
    op(&mut mr, nodes - 1);
    let r = mr.node_rejoin(leave_node).unwrap();
    assert!(r.rejoin);
    assert_eq!(r.node, leave_node);
    assert_eq!(mr.membership_epoch(), 2);
    assert_eq!(mr.active_nodes(), nodes);
    assert!(mr.departed_nodes().is_empty());
    // rejoin skips the detection phase, so it is strictly cheaper
    assert!(r.recovery_us < l.recovery_us, "{} vs {}", r.recovery_us, l.recovery_us);
    op(&mut mr, nodes);
    samples.push(l.recovery_us);
    samples.push(r.recovery_us);

    // -- a whole rack dying is ONE detection event, one budget --
    let mut mr = MultiRail::new(cfg).unwrap();
    op(&mut mr, nodes);
    let rec = mr.nodes_leave(rack).unwrap();
    assert_eq!(rec.count, rack.len());
    assert_eq!(mr.membership_epoch(), 1, "batch leave bumps the epoch once");
    assert_eq!(mr.exceptions.membership_count(), 1, "batch leave charges once");
    assert_eq!(mr.active_nodes(), nodes - rack.len());
    op(&mut mr, nodes - rack.len());
    samples.push(rec.recovery_us);

    // -- leave lands mid-op: applied at the next op boundary --
    let mut mr = MultiRail::new(cfg)
        .unwrap()
        .with_membership(MembershipSchedule::none().leave(leave_node, 1.0));
    // the first op starts at t=0, before the event: full membership
    op(&mut mr, nodes);
    assert_eq!(mr.membership_epoch(), 0, "mid-op event must wait for the boundary");
    // the clock passed 1.0 during the op; the next op applies the leave
    op(&mut mr, nodes - 1);
    assert_eq!(mr.membership_epoch(), 1);
    assert_eq!(mr.departed_nodes(), &[leave_node]);
    for ev in &mr.exceptions.membership {
        samples.push(ev.recovery_us);
    }
}

fn churn_matrix(exec: ExecMode) {
    let mut samples = Vec::new();
    churn_scenarios(&flat(8, exec), 2, &[4, 5, 6, 7], &mut samples);
    // racked-pods: node 2 inside rack 0, then rack 0 (nodes 0..4) at once
    churn_scenarios(&racked(exec), 2, &[0, 1, 2, 3], &mut samples);
    assert_eq!(samples.len(), 10, "4 scenarios x 2 shapes: 5 recoveries each");
    for &s in &samples {
        assert!(s < PAPER_RECOVERY_BUDGET_US, "recovery {s} over budget");
    }
    let p99 = percentile(&samples, 0.99).unwrap();
    assert!(
        p99 < PAPER_RECOVERY_BUDGET_US,
        "p99 recovery {p99} exceeds the {PAPER_RECOVERY_BUDGET_US}us budget"
    );
}

#[test]
fn churn_matrix_recovers_within_budget_serial() {
    churn_matrix(ExecMode::Serial);
}

#[test]
fn churn_matrix_recovers_within_budget_parallel() {
    churn_matrix(ExecMode::Parallel);
}

#[test]
fn survivors_bit_exact_vs_fresh_run_at_survivor_count() {
    // numerics on the surviving set must match a coordinator that was
    // BORN with the survivor count — schedules differ (the rebound one
    // replans over the shrunken topology), results may not
    let mut churned = MultiRail::new(&flat(8, ExecMode::Serial)).unwrap();
    op(&mut churned, 8);
    churned.node_leave(7).unwrap();
    let mut a = make(7, LEN);
    churned.allreduce_scaled(&mut a, ELEM_BYTES).unwrap();

    let mut fresh = MultiRail::new(&flat(7, ExecMode::Serial)).unwrap();
    let mut b = make(7, LEN);
    fresh.allreduce_scaled(&mut b, ELEM_BYTES).unwrap();

    for n in 0..7 {
        assert_eq!(a.node(n), b.node(n), "survivor numerics diverge at node {n}");
    }
}

#[test]
fn rejoined_cluster_bit_exact_vs_never_failed_run() {
    let c = racked(ExecMode::Serial);
    let mut churned = MultiRail::new(&c).unwrap();
    op(&mut churned, 32);
    churned.node_leave(5).unwrap();
    op(&mut churned, 31);
    churned.node_rejoin(5).unwrap();
    let mut a = make(32, LEN);
    churned.allreduce_scaled(&mut a, ELEM_BYTES).unwrap();

    let mut steady = MultiRail::new(&c).unwrap();
    let mut b = make(32, LEN);
    steady.allreduce_scaled(&mut b, ELEM_BYTES).unwrap();

    for n in 0..32 {
        assert_eq!(a.node(n), b.node(n), "rejoin numerics diverge at node {n}");
    }
}

#[test]
fn membership_epoch_keys_the_plan_cache() {
    let mut mr = MultiRail::new(&flat(8, ExecMode::Serial)).unwrap();
    // warm: repeated same-size ops settle onto a cached plan
    for _ in 0..6 {
        op(&mut mr, 8);
    }
    let settled = mr.plan_epoch();
    op(&mut mr, 8);
    assert_eq!(mr.plan_epoch(), settled, "warm cache must be reused");
    // the leave invalidates every cached plan through the epoch key
    mr.node_leave(3).unwrap();
    op(&mut mr, 7);
    assert!(
        mr.plan_epoch() > settled,
        "stale pre-churn plan must not be replayed after the rebind"
    );
    // and the post-churn cache settles again at the new epoch
    for _ in 0..6 {
        op(&mut mr, 7);
    }
    let resettled = mr.plan_epoch();
    op(&mut mr, 7);
    assert_eq!(mr.plan_epoch(), resettled, "post-churn cache must be reused");
}

#[test]
fn racked_leave_respects_shrunken_affinity_and_keeps_reducing() {
    // racks of 4 with alternating rail affinity: losing a whole rack drops
    // its mask; the rebound cluster keeps reducing on the allowed rails
    let mut c = racked(ExecMode::Serial);
    c.cluster = ClusterSpec::racked_pods(4, 16)
        .with_affinity(0, vec![0b01, 0b11, 0b11, 0b11, 0b11, 0b11, 0b11, 0b11]);
    let mut mr = MultiRail::new(&c).unwrap();
    op(&mut mr, 32);
    // rack 0 (the 0b01-constrained one) departs entirely
    mr.nodes_leave(&[0, 1, 2, 3]).unwrap();
    assert_eq!(mr.active_nodes(), 28);
    op(&mut mr, 28);
    assert!(mr.exceptions.membership_within_budget());
}

#[test]
fn membership_errors_are_atomic() {
    let mut mr = MultiRail::new(&flat(8, ExecMode::Serial)).unwrap();
    op(&mut mr, 8);
    assert!(mr.node_leave(8).is_err(), "node outside the cluster");
    assert!(mr.node_rejoin(0).is_err(), "rejoin of a never-departed node");
    assert!(mr.nodes_leave(&[1, 1]).is_err(), "duplicate in one batch");
    // a failed change leaves membership untouched and ops keep working
    assert_eq!(mr.membership_epoch(), 0);
    assert_eq!(mr.active_nodes(), 8);
    assert!(mr.departed_nodes().is_empty());
    op(&mut mr, 8);
    // shrinking below two participants is refused, membership unchanged
    mr.nodes_leave(&[1, 2, 3, 4, 5, 6]).unwrap();
    assert!(mr.node_leave(7).is_err(), "a collective needs 2 nodes");
    assert_eq!(mr.active_nodes(), 2);
    op(&mut mr, 2);
}
