//! Integration: the Control Module's paper-claimed behaviours — cold/hot
//! thresholds (Eq. 6), τ filtering (Eq. 3), α convergence within 100
//! iterations (paper §4.3), and allocation ratios by combo (Fig. 11).

use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::control::LoadBalancer;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::topology::parse_combo;

fn cfg(combo: &str, nodes: usize) -> Config {
    Config {
        nodes,
        combo: parse_combo(combo).unwrap(),
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    }
}

fn warm(mr: &mut MultiRail, bytes: u64, ops: usize) {
    const ELEMS: usize = 1024;
    for _ in 0..ops {
        let mut buf = UnboundBuffer::from_fn(mr.fab.nodes, ELEMS, |n, i| ((n + i) % 7) as f32);
        mr.allreduce_scaled(&mut buf, bytes as f64 / ELEMS as f64).unwrap();
    }
}

#[test]
fn cold_hot_threshold_in_paper_band() {
    // paper Fig. 9: 256KB at 4 nodes, 128KB at 8 nodes for dual TCP
    for (nodes, lo, hi) in [(4usize, 64u64 << 10, 512 << 10), (8, 32 << 10, 512 << 10)] {
        let c = cfg("tcp-tcp", nodes);
        let mr = MultiRail::new(&c).unwrap();
        let mut lb = LoadBalancer::new(c.control.clone());
        let th = lb.threshold_bytes(&mr.fab, &mr.timer, &[0, 1]);
        assert!(
            (lo..=hi).contains(&th),
            "{nodes} nodes: threshold {th} outside [{lo},{hi}]"
        );
    }
}

#[test]
fn convergence_within_100_iterations() {
    // paper §4.3: "threshold search and coefficient convergence within the
    // first 100 iterations"
    let mut mr = MultiRail::new(&cfg("tcp-glex", 4)).unwrap();
    let bytes = 16u64 << 20;
    warm(&mut mr, bytes, 100);
    match mr.partitioner.alphas(bytes) {
        Some(alphas) => {
            let sum: f64 = alphas.iter().map(|(_, a)| a).sum();
            assert!((sum - 1.0).abs() < 1e-6);
            // converged alphas must equalize rail finish times within ~15%
            let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n + i) % 7) as f32);
            let rep = mr.allreduce_scaled(&mut buf, bytes as f64 / 1024.0).unwrap();
            let times: Vec<f64> = rep
                .per_rail
                .iter()
                .filter(|s| s.bytes > 0)
                .map(|s| s.time_us)
                .collect();
            assert_eq!(times.len(), 2);
            let err = (times[0] - times[1]).abs() / times[0].max(times[1]);
            assert!(err < 0.15, "scheduling error {err} (paper: within 9.3%)");
        }
        None => panic!("16MB on TCP-GLEX should be hot"),
    }
}

#[test]
fn tau_gates_partitioning_by_size() {
    // TCP-SHARP: at 32KB throughput ratio >> 5 → cold; at 16MB the planes
    // are comparable → hot
    let mut mr = MultiRail::new(&cfg("tcp-sharp", 4)).unwrap();
    warm(&mut mr, 32 << 10, 10);
    warm(&mut mr, 16 << 20, 40);
    assert!(mr.partitioner.alphas(32 << 10).is_none(), "32KB must stay cold");
    assert!(mr.partitioner.alphas(16 << 20).is_some(), "16MB must go hot");
}

#[test]
fn allocation_ratio_favors_rdma_and_varies_by_size() {
    let mut mr = MultiRail::new(&cfg("tcp-glex", 4)).unwrap();
    warm(&mut mr, 4 << 20, 60);
    warm(&mut mr, 64 << 20, 60);
    let a4 = mr
        .partitioner
        .alphas(4 << 20)
        .unwrap()
        .iter()
        .find(|(r, _)| *r == 1)
        .unwrap()
        .1;
    let a64 = mr
        .partitioner
        .alphas(64 << 20)
        .unwrap()
        .iter()
        .find(|(r, _)| *r == 1)
        .unwrap()
        .1;
    assert!(a4 > 0.5, "GLEX should carry the majority at 4MB: {a4}");
    assert!(a64 > 0.5, "GLEX should carry the majority at 64MB: {a64}");
    // paper Fig. 11: ratios are size-dependent, drifting toward the
    // bandwidth ratio as setup amortizes
    assert!((a4 - a64).abs() > 0.005 || (a4 - a64).abs() < 0.5);
}

#[test]
fn timer_window_damps_outliers() {
    let c = cfg("tcp-tcp", 4);
    let mut mr = MultiRail::new(&c).unwrap();
    // record a big outlier manually; planner estimates must not explode
    warm(&mut mr, 8 << 20, 20);
    mr.timer.record(0, 8 << 20, 1e9);
    let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n + i) % 7) as f32);
    let rep = mr.allreduce_scaled(&mut buf, (8 << 20) as f64 / 1024.0).unwrap();
    assert!(rep.total_us < 100_000.0);
}

#[test]
fn static_vs_adaptive_core_allocation() {
    use nezha::net::cpu_pool::{AllocPolicy, CpuPool, Phase};
    use nezha::net::protocol::ProtoKind;
    // paper §2.3.2: static equal partitioning starves scalable protocols
    let mut stat = CpuPool::new(52.0, AllocPolicy::StaticEqual);
    let mut adap = CpuPool::new(52.0, AllocPolicy::Adaptive);
    for p in [&mut stat, &mut adap] {
        p.register(ProtoKind::Tcp);
        p.register(ProtoKind::Glex);
        p.register(ProtoKind::Sharp);
    }
    let g_static = stat.cores_for(ProtoKind::Glex, Phase::Computation);
    let g_adaptive = adap.cores_for(ProtoKind::Glex, Phase::Computation);
    assert!(g_adaptive > g_static, "{g_adaptive} vs {g_static}");
}
