//! Integration: fault tolerance (paper §4.4 / Fig. 8) — detection +
//! migration under the 200 ms budget, payload integrity across failovers,
//! re-admission after recovery, mid-op replanning of surviving rails, and
//! behaviour when all rails die.

use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::control::exception::PAPER_RECOVERY_BUDGET_US;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::fault::FaultSchedule;
use nezha::net::topology::{parse_combo, ClusterSpec};

fn cfg(combo: &str, policy: Policy) -> Config {
    Config {
        nodes: 4,
        combo: parse_combo(combo).unwrap(),
        policy,
        deterministic: true,
        ..Config::default()
    }
}

fn big_buf() -> (UnboundBuffer, Vec<f32>) {
    let nodes = 4;
    let len = 1 << 20; // 4MB
    let buf = UnboundBuffer::from_fn(nodes, len, |n, i| ((n * 3 + i) % 11) as f32);
    let expect = (0..len)
        .map(|i| (0..nodes).map(|n| ((n * 3 + i) % 11) as f32).sum())
        .collect();
    (buf, expect)
}

fn check(buf: &UnboundBuffer, expect: &[f32]) {
    for n in 0..buf.nodes() {
        for i in (0..expect.len()).step_by(4097) {
            assert_eq!(buf.node(n)[i], expect[i], "node {n} elem {i}");
        }
    }
}

#[test]
fn fig8_scenario_failover_and_recovery() {
    let mut mr = MultiRail::new(&cfg("tcp-tcp", Policy::Nezha))
        .unwrap()
        .with_faults(FaultSchedule::fig8());
    const MIN: f64 = 60.0 * 1e6;
    let mut failovers = 0;
    // 8MB modeled ops on small real buffers (timing is what matters here;
    // numerics-under-failover is covered by the tests below)
    while mr.fab.now_us() < 5.5 * MIN {
        let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n * 3 + i) % 11) as f32);
        let rep = mr.allreduce_scaled(&mut buf, 8192.0).unwrap();
        failovers += rep.failovers;
        let expect: f32 = (0..4).map(|n| ((n * 3 + 9) % 11) as f32).sum();
        assert_eq!(buf.node(0)[9], expect);
    }
    // two fault windows -> at least two failovers (one per window)
    assert!(failovers >= 2, "failovers {failovers}");
    // every recovery within the paper's 200ms budget
    for ev in &mr.exceptions.events {
        assert!(ev.recovery_us < 200_000.0, "{ev:?}");
        assert_eq!(ev.failed_rail, 1);
        assert_eq!(ev.takeover_rail, 0);
    }
    // rail 1 must be back in service after minute 5
    let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n + i) % 7) as f32);
    let rep = mr.allreduce_scaled(&mut buf, 8192.0).unwrap();
    assert_eq!(
        rep.per_rail.iter().filter(|s| s.bytes > 0).count(),
        2,
        "rail 1 not re-admitted"
    );
}

#[test]
fn failover_charges_detection_plus_migration() {
    let c = cfg("tcp-tcp", Policy::Nezha);
    let budget = c.control.detect_timeout_us + c.control.migrate_cost_us;
    let mut mr = MultiRail::new(&c)
        .unwrap()
        .with_faults(FaultSchedule::none().with(1, 0.0, 1e12));
    let (mut buf, expect) = big_buf();
    let rep = mr.allreduce(&mut buf).unwrap();
    check(&buf, &expect);
    assert_eq!(rep.failovers, 1);
    // op must be slower than a clean op by at least the recovery budget
    let mut clean = MultiRail::new(&cfg("tcp", Policy::SingleRail)).unwrap();
    let (mut buf2, _) = big_buf();
    let t_clean = clean.allreduce(&mut buf2).unwrap().total_us;
    assert!(rep.total_us > t_clean + budget * 0.9, "{} vs {}", rep.total_us, t_clean);
}

#[test]
fn mptcp_failover_also_recovers() {
    let mut mr = MultiRail::new(&cfg("tcp-tcp", Policy::Mptcp))
        .unwrap()
        .with_faults(FaultSchedule::none().with(0, 0.0, 1e12));
    let (mut buf, expect) = big_buf();
    let rep = mr.allreduce(&mut buf).unwrap();
    check(&buf, &expect);
    assert_eq!(rep.failovers, 1);
}

#[test]
fn all_rails_down_surfaces_error() {
    let mut mr = MultiRail::new(&cfg("tcp-tcp", Policy::Nezha))
        .unwrap()
        .with_faults(
            FaultSchedule::none().with(0, 0.0, 1e12).with(1, 0.0, 1e12),
        );
    let (mut buf, _) = big_buf();
    assert!(mr.allreduce(&mut buf).is_err());
}

#[test]
fn flapping_rail_multiple_failovers() {
    // rail 1 flaps: down in many short windows; every op must complete
    let mut faults = FaultSchedule::none();
    for k in 0..10 {
        let start = 0.3e6 * (2 * k + 1) as f64;
        faults = faults.with(1, start, start + 0.2e6);
    }
    let mut mr = MultiRail::new(&cfg("tcp-tcp", Policy::Nezha))
        .unwrap()
        .with_faults(faults);
    let mut total_failovers = 0;
    for _ in 0..40 {
        // 64MB modeled ops (~150ms virtual) so the run spans many windows
        let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n * 3 + i) % 11) as f32);
        let rep = mr.allreduce_scaled(&mut buf, 65536.0).unwrap();
        total_failovers += rep.failovers;
        let expect: f32 = (0..4).map(|n| ((n * 3 + 5) % 11) as f32).sum();
        assert_eq!(buf.node(3)[5], expect);
    }
    assert!(total_failovers >= 2, "flapping produced {total_failovers} failovers");
}

#[test]
fn mid_op_failover_replans_survivors_within_budget() {
    // 16-node pods topology, 4 TCP rails; rail 1 dies mid-op. The §4.4
    // handler must migrate the failed window AND the surviving rails'
    // pending windows must be re-planned (a fresh selection epoch), with
    // the recovery inside the paper's 200 ms budget.
    let mut c = cfg("tcp-tcp-tcp-tcp", Policy::Nezha);
    c.cluster = ClusterSpec::pods(4);
    c.nodes = 16;
    let mut mr = MultiRail::new(&c)
        .unwrap()
        .with_faults(FaultSchedule::none().with(1, 0.0, 1e12));
    let len = 1 << 16;
    let mut buf = UnboundBuffer::from_fn(16, len, |n, i| ((n * 5 + i) % 13) as f32);
    // one clean-state probe of what the planner WOULD do (no epoch): all
    // four rails participate before the fault surfaces
    let bytes = 256u64 << 20;
    let preview = mr.plan_for(bytes).unwrap();
    assert!(preview.active_rails() >= 2, "{preview:?}");
    let epoch_before = mr.plan_epoch();
    let rep = mr
        .allreduce_scaled(&mut buf, bytes as f64 / len as f64)
        .unwrap();
    assert_eq!(rep.failovers, 1);
    // plan epoch bumped at least twice: the op's own selection pass plus
    // the mid-op failover replan of the surviving rails
    assert!(
        mr.plan_epoch() >= epoch_before + 2,
        "epoch {} -> {} (no mid-op replan?)",
        epoch_before,
        mr.plan_epoch()
    );
    // recovery within the simulated 200 ms budget of §4.4
    assert_eq!(mr.exceptions.failover_count(), 1);
    assert!(mr.exceptions.all_within_budget());
    for ev in &mr.exceptions.events {
        assert!(ev.recovery_us < PAPER_RECOVERY_BUDGET_US, "{ev:?}");
        assert_eq!(ev.failed_rail, 1);
    }
    // numerics survive the failover + replan
    for i in (0..len).step_by(2039) {
        let expect: f32 = (0..16).map(|n| ((n * 5 + i) % 13) as f32).sum();
        assert_eq!(buf.node(0)[i], expect, "elem {i}");
    }
    // the next op re-plans for the reduced rail set (fresh cache key) and
    // completes without further failovers
    let mut buf2 = UnboundBuffer::from_fn(16, 1024, |n, i| ((n + i) % 7) as f32);
    let rep2 = mr
        .allreduce_scaled(&mut buf2, bytes as f64 / 1024.0)
        .unwrap();
    assert_eq!(rep2.failovers, 0);
    assert!(mr.plan_epoch() > epoch_before + 2);
}

#[test]
fn sharp_rail_failure_falls_back_to_tcp() {
    let mut mr = MultiRail::new(&cfg("tcp-sharp", Policy::Nezha))
        .unwrap()
        .with_faults(FaultSchedule::none().with(1, 0.0, 1e12));
    // small payload would cold-start on SHARP; its failure must migrate
    // the window to TCP
    let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n + i) % 5) as f32);
    let rep = mr.allreduce(&mut buf).unwrap();
    assert_eq!(rep.failovers, 1);
    assert_eq!(mr.fab.healthy_rails(), vec![0]);
    let expect: f32 = (0..4).map(|n| ((n + 9) % 5) as f32).sum();
    assert_eq!(buf.node(1)[9], expect);
}

#[test]
fn parallel_executor_mid_op_failover_replans_and_recovers() {
    use nezha::net::cpu_pool::ExecMode;
    // rail 1 dies mid-op under the parallel executor: the failed rail's
    // window (whose numerics never ran — timing precedes numerics) must
    // migrate to the survivor after the join, the plan cache must flush
    // (fresh selection epoch), and the payload must still reduce exactly
    let mut c = cfg("tcp-tcp", Policy::Nezha);
    c.exec = ExecMode::Parallel;
    let mut mr = MultiRail::new(&c)
        .unwrap()
        .with_faults(FaultSchedule::none().with(1, 0.0, 1e12));
    let (mut buf, expect) = big_buf();
    let e_before = mr.plan_epoch();
    let rep = mr.allreduce(&mut buf).unwrap();
    assert_eq!(rep.failovers, 1);
    check(&buf, &expect);
    assert!(
        mr.plan_epoch() > e_before,
        "failover must start a fresh selection epoch"
    );
    assert_eq!(mr.fab.healthy_rails(), vec![0]);
    assert!(mr.exceptions.all_within_budget());
    // the whole payload was accounted to the survivor
    let total: u64 = rep.per_rail.iter().map(|s| s.bytes).sum();
    assert_eq!(total, rep.bytes);
    // next op proceeds single-rail (serial fallback: one live rail)
    let (mut buf2, expect2) = big_buf();
    let rep2 = mr.allreduce(&mut buf2).unwrap();
    assert_eq!(rep2.failovers, 0);
    check(&buf2, &expect2);
}

#[test]
fn racked_pods_mid_op_failover_respects_affinity_masks() {
    use nezha::coordinator::planner::Schedule;
    // 32-node racked-pods cluster (racks of 4 inside pods of 16), three
    // TCP rails; both pods' affinity masks allow rails {0, 2} only. Rail 0
    // dies mid-op while running an inner-level-bearing multi-level
    // schedule: the §4.4 handler must migrate its window to rail 2 — never
    // the healthy-but-affinity-excluded rail 1 — replan the survivors at a
    // fresh selection epoch, and stay inside the 200 ms budget.
    let mut c = cfg("tcp-tcp-tcp", Policy::Nezha);
    c.cluster = ClusterSpec::racked_pods(4, 16).with_affinity(1, vec![0b101, 0b101]);
    c.nodes = 32;
    let mut mr = MultiRail::new(&c)
        .unwrap()
        .with_faults(FaultSchedule::none().with(0, 0.0, 1e12));
    let len = 1 << 14;
    let bytes = 256u64 << 20;
    // what the planner would run on the failing rail: a hierarchical
    // schedule with inner-level phases (timed before the fallible inter
    // ring, so the failure surfaces mid-schedule, after the rack/pod
    // phases were modeled)
    let preview = mr.plan_for(bytes).unwrap();
    assert!(
        preview
            .assignments
            .iter()
            .all(|a| a.rail == 0 || a.rail == 2),
        "affinity must exclude rail 1 from planning: {preview:?}"
    );
    assert!(
        preview
            .assignments
            .iter()
            .filter(|a| a.bytes > 0)
            .any(|a| matches!(a.schedule, Schedule::MultiLevel { .. } | Schedule::TwoLevel { .. })),
        "expected a hierarchical schedule on the racked-pods cluster: {}",
        preview.label()
    );
    let epoch_before = mr.plan_epoch();
    let mut buf = UnboundBuffer::from_fn(32, len, |n, i| ((n * 5 + i) % 13) as f32);
    let rep = mr.allreduce_scaled(&mut buf, bytes as f64 / len as f64).unwrap();
    assert_eq!(rep.failovers, 1);
    // takeover respected the masks and the budget
    assert_eq!(mr.exceptions.failover_count(), 1);
    for ev in &mr.exceptions.events {
        assert_eq!(ev.failed_rail, 0);
        assert_eq!(ev.takeover_rail, 2, "takeover must skip affinity-excluded rail 1");
        assert!(ev.recovery_us < PAPER_RECOVERY_BUDGET_US, "{ev:?}");
    }
    assert!(mr.exceptions.all_within_budget());
    assert!(mr.plan_epoch() > epoch_before, "failover must start a fresh epoch");
    // rail 1 never carried payload, before or after the failover
    assert!(rep.per_rail.iter().all(|s| s.rail != 1 || s.bytes == 0), "{rep:?}");
    // numerics survive the failover + replan
    for i in (0..len).step_by(2039) {
        let expect: f32 = (0..32).map(|n| ((n * 5 + i) % 13) as f32).sum();
        assert_eq!(buf.node(0)[i], expect, "elem {i}");
    }
    // the next op proceeds on the allowed survivor only
    let mut buf2 = UnboundBuffer::from_fn(32, 1024, |n, i| ((n + i) % 7) as f32);
    let rep2 = mr.allreduce_scaled(&mut buf2, bytes as f64 / 1024.0).unwrap();
    assert_eq!(rep2.failovers, 0);
    for s in &rep2.per_rail {
        assert!(s.rail != 1 || s.bytes == 0, "{rep2:?}");
    }
}

#[test]
fn priority_sched_failover_of_cross_iteration_in_flight_op_within_budget() {
    use nezha::net::cpu_pool::SchedMode;
    use nezha::trainer::{CommProfile, DdpSim};
    // Barrier-free training (DESIGN.md §13) keeps collectives in flight
    // across iteration boundaries; a rail dying mid-run hits one of those
    // in-flight ops. The §4.4 handler must still recover inside the 200 ms
    // budget, the reduced gradients must stay bit-identical to a
    // fault-free twin (failover migrates windows, never changes sums),
    // and the wire timeline must drain without deadlock.
    let mk = || {
        let mut c = cfg("tcp-tcp", Policy::Nezha);
        c.sched = SchedMode::Priority;
        DdpSim::new(&c, CommProfile::alexnet(), 1, 32).unwrap()
    };
    let mut clean = mk();
    let mut faulty = mk();
    clean.warmup(3).unwrap();
    faulty.warmup(3).unwrap();
    assert!(
        faulty.sched_stats().cross_boundary_ops >= 1,
        "no op was in flight across a boundary before the fault"
    );
    // rail 1 goes down from the current fabric instant — the next ops
    // (including buckets already priced into the in-flight timeline's
    // successors) hit the window mid-op
    let t0 = faulty.mr.fab.now_us();
    faulty.mr.fab.faults = FaultSchedule::none().with(1, t0, t0 + 2e6);
    for it in 0..3 {
        let tc = clean.iter_time_us().unwrap();
        let tf = faulty.iter_time_us().unwrap();
        assert!(tc > 0.0 && tf > 0.0);
        assert_eq!(
            clean.last_fingerprints(),
            faulty.last_fingerprints(),
            "failover changed gradient numerics at iteration {it}"
        );
    }
    assert!(
        faulty.mr.exceptions.failover_count() >= 1,
        "the down window never tripped a failover"
    );
    assert!(faulty.mr.exceptions.all_within_budget());
    for ev in &faulty.mr.exceptions.events {
        assert!(ev.recovery_us < PAPER_RECOVERY_BUDGET_US, "{ev:?}");
        assert_eq!(ev.failed_rail, 1);
    }
    // the timeline never wedges: every enqueued op completes
    assert!(faulty.drain_queue(), "in-flight op stuck after failover");
    assert!(clean.drain_queue());
}

#[test]
fn parallel_executor_all_rails_down_is_an_error() {
    use nezha::net::cpu_pool::ExecMode;
    let mut c = cfg("tcp-tcp", Policy::Nezha);
    c.exec = ExecMode::Parallel;
    let mut mr = MultiRail::new(&c).unwrap().with_faults(
        FaultSchedule::none().with(0, 0.0, 1e12).with(1, 0.0, 1e12),
    );
    let (mut buf, _) = big_buf();
    assert!(mr.allreduce(&mut buf).is_err());
}
