//! Integration: fault tolerance (paper §4.4 / Fig. 8) — detection +
//! migration under the 200 ms budget, payload integrity across failovers,
//! re-admission after recovery, and behaviour when all rails die.

use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::fault::FaultSchedule;
use nezha::net::topology::parse_combo;

fn cfg(combo: &str, policy: Policy) -> Config {
    Config {
        nodes: 4,
        combo: parse_combo(combo).unwrap(),
        policy,
        deterministic: true,
        ..Config::default()
    }
}

fn big_buf() -> (UnboundBuffer, Vec<f32>) {
    let nodes = 4;
    let len = 1 << 20; // 4MB
    let buf = UnboundBuffer::from_fn(nodes, len, |n, i| ((n * 3 + i) % 11) as f32);
    let expect = (0..len)
        .map(|i| (0..nodes).map(|n| ((n * 3 + i) % 11) as f32).sum())
        .collect();
    (buf, expect)
}

fn check(buf: &UnboundBuffer, expect: &[f32]) {
    for n in 0..buf.nodes() {
        for i in (0..expect.len()).step_by(4097) {
            assert_eq!(buf.node(n)[i], expect[i], "node {n} elem {i}");
        }
    }
}

#[test]
fn fig8_scenario_failover_and_recovery() {
    let mut mr = MultiRail::new(&cfg("tcp-tcp", Policy::Nezha))
        .unwrap()
        .with_faults(FaultSchedule::fig8());
    const MIN: f64 = 60.0 * 1e6;
    let mut failovers = 0;
    // 8MB modeled ops on small real buffers (timing is what matters here;
    // numerics-under-failover is covered by the tests below)
    while mr.fab.now_us() < 5.5 * MIN {
        let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n * 3 + i) % 11) as f32);
        let rep = mr.allreduce_scaled(&mut buf, 8192.0).unwrap();
        failovers += rep.failovers;
        let expect: f32 = (0..4).map(|n| ((n * 3 + 9) % 11) as f32).sum();
        assert_eq!(buf.node(0)[9], expect);
    }
    // two fault windows -> at least two failovers (one per window)
    assert!(failovers >= 2, "failovers {failovers}");
    // every recovery within the paper's 200ms budget
    for ev in &mr.exceptions.events {
        assert!(ev.recovery_us < 200_000.0, "{ev:?}");
        assert_eq!(ev.failed_rail, 1);
        assert_eq!(ev.takeover_rail, 0);
    }
    // rail 1 must be back in service after minute 5
    let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n + i) % 7) as f32);
    let rep = mr.allreduce_scaled(&mut buf, 8192.0).unwrap();
    assert_eq!(
        rep.per_rail.iter().filter(|s| s.bytes > 0).count(),
        2,
        "rail 1 not re-admitted"
    );
}

#[test]
fn failover_charges_detection_plus_migration() {
    let c = cfg("tcp-tcp", Policy::Nezha);
    let budget = c.control.detect_timeout_us + c.control.migrate_cost_us;
    let mut mr = MultiRail::new(&c)
        .unwrap()
        .with_faults(FaultSchedule::none().with(1, 0.0, 1e12));
    let (mut buf, expect) = big_buf();
    let rep = mr.allreduce(&mut buf).unwrap();
    check(&buf, &expect);
    assert_eq!(rep.failovers, 1);
    // op must be slower than a clean op by at least the recovery budget
    let mut clean = MultiRail::new(&cfg("tcp", Policy::SingleRail)).unwrap();
    let (mut buf2, _) = big_buf();
    let t_clean = clean.allreduce(&mut buf2).unwrap().total_us;
    assert!(rep.total_us > t_clean + budget * 0.9, "{} vs {}", rep.total_us, t_clean);
}

#[test]
fn mptcp_failover_also_recovers() {
    let mut mr = MultiRail::new(&cfg("tcp-tcp", Policy::Mptcp))
        .unwrap()
        .with_faults(FaultSchedule::none().with(0, 0.0, 1e12));
    let (mut buf, expect) = big_buf();
    let rep = mr.allreduce(&mut buf).unwrap();
    check(&buf, &expect);
    assert_eq!(rep.failovers, 1);
}

#[test]
fn all_rails_down_surfaces_error() {
    let mut mr = MultiRail::new(&cfg("tcp-tcp", Policy::Nezha))
        .unwrap()
        .with_faults(
            FaultSchedule::none().with(0, 0.0, 1e12).with(1, 0.0, 1e12),
        );
    let (mut buf, _) = big_buf();
    assert!(mr.allreduce(&mut buf).is_err());
}

#[test]
fn flapping_rail_multiple_failovers() {
    // rail 1 flaps: down in many short windows; every op must complete
    let mut faults = FaultSchedule::none();
    for k in 0..10 {
        let start = 0.3e6 * (2 * k + 1) as f64;
        faults = faults.with(1, start, start + 0.2e6);
    }
    let mut mr = MultiRail::new(&cfg("tcp-tcp", Policy::Nezha))
        .unwrap()
        .with_faults(faults);
    let mut total_failovers = 0;
    for _ in 0..40 {
        // 64MB modeled ops (~150ms virtual) so the run spans many windows
        let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n * 3 + i) % 11) as f32);
        let rep = mr.allreduce_scaled(&mut buf, 65536.0).unwrap();
        total_failovers += rep.failovers;
        let expect: f32 = (0..4).map(|n| ((n * 3 + 5) % 11) as f32).sum();
        assert_eq!(buf.node(3)[5], expect);
    }
    assert!(total_failovers >= 2, "flapping produced {total_failovers} failovers");
}

#[test]
fn sharp_rail_failure_falls_back_to_tcp() {
    let mut mr = MultiRail::new(&cfg("tcp-sharp", Policy::Nezha))
        .unwrap()
        .with_faults(FaultSchedule::none().with(1, 0.0, 1e12));
    // small payload would cold-start on SHARP; its failure must migrate
    // the window to TCP
    let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n + i) % 5) as f32);
    let rep = mr.allreduce(&mut buf).unwrap();
    assert_eq!(rep.failovers, 1);
    assert_eq!(mr.fab.healthy_rails(), vec![0]);
    let expect: f32 = (0..4).map(|n| ((n + 9) % 5) as f32).sum();
    assert_eq!(buf.node(1)[9], expect);
}
