//! Integration: gray-failure resilience (DESIGN.md §11) — seeded chaos
//! campaigns composing loss, brownouts, flaps, windowed stragglers,
//! crash-stop windows and node churn, held to the three campaign
//! invariants (bit-exact numerics vs a fault-free twin, recovery within
//! the paper's 200 ms budget, bounded health-transition oscillation) on
//! both executors, plus targeted loss-determinism and flap tests.

use nezha::bench::chaos::{campaign, run_campaign, CHAOS_OSC_BOUND};
use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::control::HealthMode;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::cpu_pool::ExecMode;
use nezha::net::fault::DegradeSchedule;
use nezha::net::protocol::ProtoKind;
use nezha::net::rail::RailHealth;

const SEEDS: [u64; 8] = [1, 2, 3, 5, 8, 13, 21, 34];

fn cfg(exec: ExecMode) -> Config {
    let mut c = Config {
        nodes: 4,
        combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    c.exec = exec;
    c
}

fn make(nodes: usize, len: usize) -> UnboundBuffer {
    UnboundBuffer::from_fn(nodes, len, |n, i| ((n + 1) * (i % 13 + 1)) as f32)
}

/// The chaos matrix: every seed, both executors, all three invariants.
#[test]
fn chaos_campaign_matrix_holds_all_invariants() {
    for &seed in &SEEDS {
        let c = campaign(seed);
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            let o = run_campaign(&c, exec, HealthMode::Graceful).unwrap();
            assert!(
                o.bit_exact,
                "seed {seed} {}: numerics diverged from the fault-free twin ({})",
                o.exec, o.label
            );
            assert!(
                o.within_budget,
                "seed {seed} {}: recovery budget blown ({})",
                o.exec, o.label
            );
            assert!(
                o.max_rail_transitions <= CHAOS_OSC_BOUND,
                "seed {seed} {}: oscillation {} > {CHAOS_OSC_BOUND} ({})",
                o.exec, o.max_rail_transitions, o.label
            );
        }
    }
}

/// Campaign verdicts are themselves executor-invariant: the serial and
/// parallel runs of the same seed see identical failover and gray-event
/// counts, not just identical numerics.
#[test]
fn chaos_campaign_bookkeeping_is_executor_invariant() {
    for &seed in &[1u64, 5, 21] {
        let c = campaign(seed);
        let s = run_campaign(&c, ExecMode::Serial, HealthMode::Graceful).unwrap();
        let p = run_campaign(&c, ExecMode::Parallel, HealthMode::Graceful).unwrap();
        assert_eq!(s.failovers, p.failovers, "seed {seed}");
        assert_eq!(s.gray_events, p.gray_events, "seed {seed}");
        assert_eq!(s.max_rail_transitions, p.max_rail_transitions, "seed {seed}");
    }
}

/// Retry sampling rides the per-rail RNG streams: with loss active the
/// sampled retransmit charges — and therefore every modeled time — are
/// bit-identical between the serial and parallel executors.
#[test]
fn loss_retransmits_bit_identical_across_executors() {
    let degrade = DegradeSchedule::none().loss(1, 0.0, 1e12, 0.08);
    let mut serial = MultiRail::new(&cfg(ExecMode::Serial))
        .unwrap()
        .with_degrade(degrade.clone());
    let mut parallel = MultiRail::new(&cfg(ExecMode::Parallel))
        .unwrap()
        .with_degrade(degrade);
    let len = 1 << 20; // 4MB: hot → both rails
    for op in 0..6 {
        let mut bs = make(4, len);
        let mut bp = make(4, len);
        let rs = serial.allreduce(&mut bs).unwrap();
        let rp = parallel.allreduce(&mut bp).unwrap();
        assert_eq!(rs.total_us, rp.total_us, "op {op}: sampled retransmits diverged");
        for (a, b) in rs.per_rail.iter().zip(&rp.per_rail) {
            assert_eq!(a.time_us, b.time_us, "op {op} rail {}", a.rail);
            assert_eq!(a.bytes, b.bytes, "op {op} rail {}", a.rail);
        }
        for n in 0..4 {
            assert_eq!(bs.node(n), bp.node(n), "op {op} node {n}");
        }
    }
    assert_eq!(
        serial.fab.retries_on(1),
        parallel.fab.retries_on(1),
        "retry ledgers must match"
    );
    assert!(serial.fab.retries_on(1) > 0, "loss must actually charge retries");
}

/// Loss and brownouts stretch modeled time but never touch payload bytes:
/// a degraded run reduces bit-exactly like a clean one.
#[test]
fn degradation_never_corrupts_numerics() {
    let degrade = DegradeSchedule::none()
        .loss(1, 0.0, 1e12, 0.1)
        .brownout(0, 0.0, 1e12, 0.6)
        .stall(1, 0.0, 1e12, 3_000.0, 0.2);
    let mut dirty = MultiRail::new(&cfg(ExecMode::Serial))
        .unwrap()
        .with_degrade(degrade);
    let mut clean = MultiRail::new(&cfg(ExecMode::Serial)).unwrap();
    let len = 1 << 20;
    for op in 0..4 {
        let mut a = make(4, len);
        let mut b = make(4, len);
        let rep_dirty = dirty.allreduce(&mut a).unwrap();
        let rep_clean = clean.allreduce(&mut b).unwrap();
        for n in 0..4 {
            assert_eq!(a.node(n), b.node(n), "op {op} node {n}");
        }
        assert!(
            rep_dirty.total_us > rep_clean.total_us,
            "op {op}: degradation must cost time ({} vs {})",
            rep_dirty.total_us,
            rep_clean.total_us
        );
    }
}

/// A flapping rail is crash-like in its down half-periods: it rides the
/// §4.4 failover, is barred from readmission while down, and the
/// quarantine dwell backoff keeps the transition count bounded — then it
/// settles back to Healthy once the flap window ends.
#[test]
fn flapping_rail_is_bounded_and_settles() {
    // 60ms half-periods over a 480ms window, then permanently clean
    let degrade = DegradeSchedule::none().flap(1, 0.0, 480_000.0, 60_000.0);
    let mut mr = MultiRail::new(&cfg(ExecMode::Serial))
        .unwrap()
        .with_degrade(degrade);
    let len = 1 << 20;
    let mut failovers = 0;
    for _ in 0..40 {
        let mut buf = make(4, len);
        let rep = mr.allreduce(&mut buf).unwrap();
        failovers += rep.failovers;
        if mr.fab.now_us() > 1_000_000.0 {
            break;
        }
    }
    assert!(failovers >= 1, "a flap down-phase must trigger a failover");
    assert!(
        mr.monitor.transition_count(1) <= CHAOS_OSC_BOUND,
        "flap oscillation must stay bounded: {:?}",
        mr.monitor.transitions()
    );
    // past the window: keep running until the quarantine dwell expires
    // and the canary is promoted
    for _ in 0..30 {
        let mut buf = make(4, len);
        mr.allreduce(&mut buf).unwrap();
        if mr.fab.rails[1].health == RailHealth::Healthy {
            break;
        }
    }
    assert_eq!(
        mr.fab.rails[1].health,
        RailHealth::Healthy,
        "the rail must settle once the flap window ends: {:?}",
        mr.monitor.transitions()
    );
    assert!(mr.exceptions.all_within_budget());
    assert!(mr.exceptions.gray_within_budget());
}

/// Gray hazards compose with the barrier-free scheduler (DESIGN.md §13):
/// barrier/priority DDP twins trained under the SAME campaign stay
/// gradient-bit-exact every iteration, hazards that hit cross-iteration
/// in-flight ops recover inside the 200 ms budget, real overlap still
/// happens, and the priority wire timeline drains without deadlock.
#[test]
fn chaos_composes_with_priority_scheduler() {
    use nezha::bench::chaos::run_scheduler_campaign;
    for &seed in &[1u64, 5, 21, 34] {
        let c = campaign(seed);
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            let o = run_scheduler_campaign(&c, exec).unwrap();
            assert!(
                o.bit_exact,
                "seed {seed} {}: priority gradients diverged from barrier under hazards ({})",
                o.exec, o.label
            );
            assert!(
                o.within_budget,
                "seed {seed} {}: recovery budget blown mid-training ({})",
                o.exec, o.label
            );
            assert!(
                o.queue_drained,
                "seed {seed} {}: the wire timeline wedged under hazards ({})",
                o.exec, o.label
            );
            assert!(
                o.overlapped,
                "seed {seed} {}: hazards killed all cross-iteration overlap ({})",
                o.exec, o.label
            );
            assert!(o.passed());
        }
    }
}

/// Graceful demotion under a brownout beats binary quarantine end to end
/// (the integration-level restatement of the grayfault ablation's
/// acceptance row).
#[test]
fn graceful_soft_demotion_beats_binary_on_brownout() {
    let mean = |mode: HealthMode| {
        let mut c = cfg(ExecMode::Serial);
        c.health.mode = mode;
        c.health.dirty_inc = 4.0; // first dirty residual crosses degrade_enter
        let mut mr = MultiRail::new(&c)
            .unwrap()
            .with_degrade(DegradeSchedule::none().brownout(1, 0.0, 1e12, 0.5));
        let elem_bytes = (16u64 << 20) as f64 / 2048.0;
        let mut total = 0.0;
        let mut counted = 0;
        for op in 0..12 {
            let mut buf = make(4, 2048);
            let rep = mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
            if op >= 2 {
                total += rep.total_us;
                counted += 1;
            }
        }
        total / counted as f64
    };
    let graceful = mean(HealthMode::Graceful);
    let binary = mean(HealthMode::Binary);
    assert!(
        graceful < binary,
        "soft demotion must beat quarantine-everything: graceful {graceful} vs binary {binary}"
    );
}
