//! Integration: end-to-end data-plane integrity (DESIGN.md §12) — seeded
//! corruption campaigns composed with loss, brownouts, crash windows and
//! node churn. With the wire checksums on, every campaign must stay
//! bit-exact vs a fault-free twin, hold the 200 ms recovery budget, and
//! quarantine the persistently-corrupting rail with bounded oscillation;
//! with the checksums ablated, the same campaigns must leak a measurable
//! escape rate. Plus targeted executor-invariance and trainer-guard
//! containment tests.

use nezha::bench::chaos::{
    corruption_campaign, run_integrity_campaign, storm_rail, CHAOS_OSC_BOUND,
};
use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::cpu_pool::ExecMode;
use nezha::net::fault::CorruptSchedule;
use nezha::net::protocol::ProtoKind;
use nezha::net::rail::RailHealth;
use nezha::trainer::comm_profile::CommProfile;
use nezha::trainer::ddp::DdpSim;

const SEEDS: [u64; 8] = [1, 2, 3, 5, 8, 13, 21, 34];

fn cfg(exec: ExecMode) -> Config {
    let mut c = Config {
        nodes: 4,
        combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    c.exec = exec;
    c
}

fn make(nodes: usize, len: usize) -> UnboundBuffer {
    UnboundBuffer::from_fn(nodes, len, |n, i| ((n + 1) * (i % 13 + 1)) as f32)
}

/// The corruption matrix with checksums ON: every seed, both executors,
/// all integrity invariants.
#[test]
fn corruption_campaign_matrix_holds_integrity_invariants() {
    for &seed in &SEEDS {
        let c = corruption_campaign(seed);
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            let o = run_integrity_campaign(&c, exec, true).unwrap();
            assert!(
                o.bit_exact,
                "seed {seed} {}: checksummed run diverged from the fault-free twin ({})",
                o.exec, o.label
            );
            assert!(o.injected > 0, "seed {seed} {}: storm must inject ({})", o.exec, o.label);
            assert!(
                o.within_budget,
                "seed {seed} {}: recovery budget blown ({})",
                o.exec, o.label
            );
            assert!(
                o.storm_quarantined,
                "seed {seed} {}: persistently-corrupting rail {} never quarantined ({})",
                o.exec,
                storm_rail(&c),
                o.label
            );
            assert!(
                o.max_rail_transitions <= CHAOS_OSC_BOUND,
                "seed {seed} {}: oscillation {} > {CHAOS_OSC_BOUND} ({})",
                o.exec, o.max_rail_transitions, o.label
            );
        }
    }
}

/// The same matrix with checksums ABLATED: poison reaches the reduction
/// and the measured escape rate is nonzero (per the acceptance criterion),
/// while the silent path charges no retransmits.
#[test]
fn ablated_checksums_leak_measured_escapes() {
    let mut escaped_total = 0usize;
    for &seed in &SEEDS {
        let c = corruption_campaign(seed);
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            let o = run_integrity_campaign(&c, exec, false).unwrap();
            assert!(o.injected > 0, "seed {seed} {}: storm must inject ({})", o.exec, o.label);
            escaped_total += o.escaped_ops;
        }
    }
    assert!(
        escaped_total > 0,
        "with checksums off, some corrupted op must escape into the reduction"
    );
}

/// Corruption sampling rides the per-rail RNG streams: with a storm
/// active the recharged retransmits — and therefore every modeled time —
/// are bit-identical between the serial and parallel executors, as are
/// the unified retry and corruption ledgers.
#[test]
fn corruption_retransmits_bit_identical_across_executors() {
    let corrupt = CorruptSchedule::none().flip(1, 0.0, 1e12, 0.08);
    let mut serial = MultiRail::new(&cfg(ExecMode::Serial))
        .unwrap()
        .with_corrupt(corrupt.clone());
    let mut parallel = MultiRail::new(&cfg(ExecMode::Parallel))
        .unwrap()
        .with_corrupt(corrupt);
    let len = 1 << 20; // 4MB: hot → both rails
    for op in 0..6 {
        let mut bs = make(4, len);
        let mut bp = make(4, len);
        let rs = serial.allreduce(&mut bs).unwrap();
        let rp = parallel.allreduce(&mut bp).unwrap();
        assert_eq!(rs.total_us, rp.total_us, "op {op}: sampled recharges diverged");
        for n in 0..4 {
            assert_eq!(bs.node(n), bp.node(n), "op {op} node {n}");
        }
    }
    assert_eq!(
        serial.fab.corruptions_on(1),
        parallel.fab.corruptions_on(1),
        "corruption ledgers must match"
    );
    assert_eq!(
        serial.fab.retries_on(1),
        parallel.fab.retries_on(1),
        "corruption recharges feed the same retry ledger on both executors"
    );
    assert!(serial.fab.corruptions_on(1) > 0, "the storm must actually corrupt");
}

/// Corruption composed with a crash window on the same rail behaves
/// exactly like the crash alone: a down rail carries nothing, so there is
/// nothing to corrupt, and the survivors keep the reduction bit-exact.
#[test]
fn corrupt_on_crashed_rail_composes_to_down() {
    let mk = |corrupt: CorruptSchedule| {
        let mut c = cfg(ExecMode::Serial);
        c.faults = nezha::net::fault::FaultSchedule::none().with(1, 0.0, 1e12);
        c.corrupt = corrupt;
        MultiRail::new(&c).unwrap()
    };
    let mut down = mk(CorruptSchedule::none());
    let mut both = mk(CorruptSchedule::none().flip(1, 0.0, 1e12, 0.5));
    let len = 1 << 20;
    for op in 0..4 {
        let mut a = make(4, len);
        let mut b = make(4, len);
        let ra = down.allreduce(&mut a).unwrap();
        let rb = both.allreduce(&mut b).unwrap();
        assert_eq!(ra.total_us, rb.total_us, "op {op}");
        for n in 0..4 {
            assert_eq!(a.node(n), b.node(n), "op {op} node {n}");
        }
    }
    assert_eq!(both.fab.corruptions_on(1), 0, "a down rail has nothing to corrupt");
}

/// A persistent storm walks the gray state machine: the rail reaches
/// Quarantined, every gray action stays inside the 200 ms budget, and
/// the clean anchor rail never transitions.
#[test]
fn storm_rail_quarantined_within_budget() {
    let mut mr = MultiRail::new(&cfg(ExecMode::Serial))
        .unwrap()
        .with_corrupt(CorruptSchedule::none().flip(1, 0.0, 1e12, 0.2));
    let len = 1 << 20;
    for _ in 0..8 {
        let mut buf = make(4, len);
        mr.allreduce(&mut buf).unwrap();
    }
    assert!(
        mr.monitor
            .transitions()
            .iter()
            .any(|t| t.rail == 1 && t.to == RailHealth::Quarantined),
        "storm rail must be quarantined: {:?}",
        mr.monitor.transitions()
    );
    assert_eq!(mr.monitor.transition_count(0), 0, "anchor rail must stay Healthy");
    assert!(mr.exceptions.gray_within_budget(), "quarantine must land inside 200 ms");
    assert!(mr.exceptions.all_within_budget());
}

/// Corruption campaigns compose with the barrier-free scheduler
/// (DESIGN.md §13): with the wire checksums on, barrier/priority DDP
/// twins under the SAME corruption storm stay gradient-bit-exact (every
/// detected corruption recharges identically in both modes), the storm
/// rail's quarantine can land while ops are in flight across an iteration
/// boundary without wedging the wire timeline, and recovery stays inside
/// the 200 ms budget.
#[test]
fn corruption_composes_with_priority_scheduler() {
    use nezha::bench::chaos::run_scheduler_campaign;
    for &seed in &[1u64, 5, 21] {
        let c = corruption_campaign(seed);
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            let o = run_scheduler_campaign(&c, exec).unwrap();
            assert!(
                o.bit_exact,
                "seed {seed} {}: priority gradients diverged from barrier under corruption ({})",
                o.exec, o.label
            );
            assert!(
                o.within_budget,
                "seed {seed} {}: recovery budget blown mid-training ({})",
                o.exec, o.label
            );
            assert!(
                o.queue_drained,
                "seed {seed} {}: quarantine wedged the wire timeline ({})",
                o.exec, o.label
            );
            assert!(
                o.overlapped,
                "seed {seed} {}: no cross-iteration overlap survived ({})",
                o.exec, o.label
            );
        }
    }
}

/// Trainer-level containment end to end: with the wire checksums ablated,
/// the per-bucket fingerprint guard catches the poisoned buckets and its
/// recompute-and-retransmit fallback restores every bucket to the
/// fault-free oracle's gradient.
#[test]
fn trainer_guard_contains_escaped_corruption() {
    let mut oracle = DdpSim::new(&cfg(ExecMode::Serial), CommProfile::alexnet(), 1, 32).unwrap();
    oracle.comm_us().unwrap();
    let expect = oracle.last_fingerprints().to_vec();

    let mut c = cfg(ExecMode::Serial);
    c.corrupt = CorruptSchedule::none().flip(1, 0.0, 1e12, 0.35);
    c.integrity = false;
    let mut guarded = DdpSim::new(&c, CommProfile::alexnet(), 1, 32)
        .unwrap()
        .with_fingerprint_guard(expect.clone());
    guarded.comm_us().unwrap();
    assert!(guarded.guard_recomputes() > 0, "poison must trip the guard");
    assert_eq!(
        guarded.last_fingerprints(),
        &expect[..],
        "containment must restore the oracle gradients"
    );
}
