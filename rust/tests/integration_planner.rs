//! Integration: the topology-aware collective planner — hierarchical
//! two-level wins on the grouped 16-node × 4-rail topology, numerics stay
//! bit-identical to the seed's fixed dispatch across plan types, plans are
//! exposed for introspection, and failover re-plans onto survivors.

use nezha::config::{Config, PlannerMode, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::coordinator::planner::Schedule;
use nezha::net::fault::FaultSchedule;
use nezha::net::topology::{parse_combo, ClusterSpec};
use nezha::util::rng::Pcg;

const ELEMS: usize = 1024;

fn cfg(cluster: ClusterSpec, combo: &str, nodes: usize, mode: PlannerMode) -> Config {
    let mut c = Config {
        cluster,
        nodes,
        combo: parse_combo(combo).unwrap(),
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    c.planner = mode;
    c
}

fn mean_lat(mr: &mut MultiRail, bytes: u64, warm: usize, reps: usize) -> f64 {
    nezha::bench::mean_allreduce_us(mr, bytes, warm, reps).unwrap()
}

#[test]
fn two_level_beats_flat_ring_on_16_node_4_rail_pods() {
    let combo = "tcp-tcp-tcp-glex";
    let mut flat = MultiRail::new(&cfg(ClusterSpec::pods(4), combo, 16, PlannerMode::Flat))
        .unwrap();
    let mut auto = MultiRail::new(&cfg(ClusterSpec::pods(4), combo, 16, PlannerMode::Auto))
        .unwrap();
    let bytes = 64u64 << 20;
    let t_flat = mean_lat(&mut flat, bytes, 30, 5);
    let t_auto = mean_lat(&mut auto, bytes, 30, 5);
    assert!(
        t_auto < 0.8 * t_flat,
        "planner {t_auto}us should clearly beat fixed dispatch {t_flat}us"
    );
    // the winning plan uses the hierarchical two-level schedule
    let plan = auto.last_plan.as_ref().expect("share policy records a plan");
    assert!(
        plan.assignments
            .iter()
            .filter(|a| a.bytes > 0)
            .any(|a| matches!(a.schedule, Schedule::TwoLevel { group: 4, .. })),
        "expected a two-level assignment, got {}",
        plan.label()
    );
}

#[test]
fn flat_cluster_planner_stays_single_level() {
    let mut mr = MultiRail::new(&cfg(ClusterSpec::local(), "tcp-tcp", 8, PlannerMode::Auto))
        .unwrap();
    let _ = mean_lat(&mut mr, 8 << 20, 5, 1);
    let plan = mr.last_plan.as_ref().unwrap();
    for a in &plan.assignments {
        assert!(
            !matches!(a.schedule, Schedule::TwoLevel { .. }),
            "flat local cluster must not go hierarchical: {plan:?}"
        );
    }
}

/// Core acceptance invariant: for identical inputs the planner's execution
/// produces bit-identical f32 results to the seed's fixed flat-ring
/// dispatch, across every plan family (two-level + chunked + tree +
/// halving-doubling all engage below), because numerics always run the
/// seed reducer over the same windows.
#[test]
fn planner_numerics_bit_identical_to_fixed_dispatch() {
    let cases: [(ClusterSpec, &str, usize, u64); 4] = [
        // two-level + tree territory
        (ClusterSpec::pods(4), "tcp-tcp-tcp-glex", 16, 64 << 20),
        // halving-doubling territory (latency-bound, hot)
        (ClusterSpec::local(), "tcp-tcp", 8, 512 << 10),
        // chunked-ring territory (bandwidth-bound)
        (ClusterSpec::local(), "tcp-tcp", 4, 256 << 20),
        // cold-start tree
        (ClusterSpec::local(), "tcp-sharp", 4, 4 << 10),
    ];
    for (i, (cluster, combo, nodes, bytes)) in cases.into_iter().enumerate() {
        let mut rng = Pcg::new(77 + i as u64);
        let data: Vec<Vec<f32>> = (0..nodes)
            .map(|_| (0..ELEMS).map(|_| rng.normal() as f32).collect())
            .collect();
        let run = |mode: PlannerMode| -> Vec<Vec<f32>> {
            let mut mr = MultiRail::new(&cfg(cluster.clone(), combo, nodes, mode)).unwrap();
            let mut buf = UnboundBuffer::new(data.clone());
            // single op from a cold coordinator: both modes see identical
            // balancer state, hence identical windows
            mr.allreduce_scaled(&mut buf, bytes as f64 / ELEMS as f64).unwrap();
            buf.into_data()
        };
        let fixed = run(PlannerMode::Flat);
        let auto = run(PlannerMode::Auto);
        for n in 0..nodes {
            assert_eq!(fixed[n], auto[n], "case {i}: node {n} diverged bitwise");
        }
    }
}

#[test]
fn plan_for_exposes_consistent_plan() {
    let mut mr = MultiRail::new(&cfg(
        ClusterSpec::pods(4),
        "tcp-tcp-tcp-glex",
        16,
        PlannerMode::Auto,
    ))
    .unwrap();
    let plan = mr.plan_for(64 << 20).expect("nezha policy plans shares");
    assert!(plan.conserves(nezha::coordinator::buffer::Window::new(0, ELEMS)));
    assert!(plan.active_rails() >= 2, "{plan:?}");
    assert!(plan.predicted_us > 0.0);
    // executing reports the same rails the plan claimed
    let bytes = 64u64 << 20;
    let mut buf = UnboundBuffer::from_fn(16, ELEMS, |n, j| ((n + j) % 7) as f32);
    let rep = mr.allreduce_scaled(&mut buf, bytes as f64 / ELEMS as f64).unwrap();
    let executed = mr.last_plan.as_ref().unwrap();
    let claimed: Vec<usize> = executed
        .assignments
        .iter()
        .filter(|a| a.bytes > 0)
        .map(|a| a.rail)
        .collect();
    let used: Vec<usize> = rep
        .per_rail
        .iter()
        .filter(|s| s.bytes > 0)
        .map(|s| s.rail)
        .collect();
    assert_eq!(claimed, used);
    let sum: u64 = rep.per_rail.iter().map(|s| s.bytes).sum();
    assert_eq!(sum, rep.bytes);
}

/// Plan-quality regression (tier-1): across the deterministic harness
/// sweep the median relative |predicted − measured| / measured error must
/// stay under the committed ceiling — cost-model drift fails the build.
#[test]
fn plan_quality_median_error_under_committed_threshold() {
    // same case list as the emitted report/CI artifact — shared via
    // bench::harness so coverage cannot silently diverge
    for (name, cluster, combo, nodes) in nezha::bench::harness::plan_quality_cases() {
        let report = nezha::bench::plan_quality_sweep(&cluster, combo, nodes, 10, 5).unwrap();
        assert!(!report.is_empty(), "{name}: sweep produced no samples");
        let median = report.median_rel_error().unwrap();
        assert!(
            median <= nezha::bench::PLAN_QUALITY_MEDIAN_ERR_MAX,
            "{name}: median prediction error {median:.4} exceeds ceiling {}",
            nezha::bench::PLAN_QUALITY_MEDIAN_ERR_MAX
        );
        // the JSON document carries the aggregate (dashboard artifact)
        let j = report.to_json();
        assert_eq!(j.get("report").and_then(|v| v.as_str()), Some("plan_quality"));
        assert!(
            j.get("median_rel_err").and_then(|v| v.as_f64()).unwrap()
                <= nezha::bench::PLAN_QUALITY_MEDIAN_ERR_MAX
        );
    }
}

#[test]
fn failover_replans_onto_survivor_with_planner() {
    let mut mr = MultiRail::new(&cfg(ClusterSpec::pods(4), "tcp-tcp", 16, PlannerMode::Auto))
        .unwrap()
        .with_faults(FaultSchedule::none().with(1, 0.0, 1e12));
    let len = 1 << 16;
    let mut buf = UnboundBuffer::from_fn(16, len, |n, i| ((n * 3 + i) % 11) as f32);
    // 64MB modeled → hot → both rails → rail 1 dies mid-op
    let rep = mr.allreduce_scaled(&mut buf, (64u64 << 20) as f64 / len as f64).unwrap();
    assert_eq!(rep.failovers, 1);
    assert_eq!(mr.fab.healthy_rails(), vec![0]);
    for i in (0..len).step_by(4097) {
        let expect: f32 = (0..16).map(|n| ((n * 3 + i) % 11) as f32).sum();
        assert_eq!(buf.node(0)[i], expect, "elem {i}");
    }
    // next op proceeds single-rail, still planned
    let mut buf2 = UnboundBuffer::from_fn(16, ELEMS, |n, i| ((n + i) % 7) as f32);
    let rep2 = mr.allreduce_scaled(&mut buf2, (64u64 << 20) as f64 / ELEMS as f64).unwrap();
    assert_eq!(rep2.failovers, 0);
    assert_eq!(rep2.per_rail.iter().filter(|s| s.bytes > 0).count(), 1);
    assert!(mr.last_plan.is_some());
}
