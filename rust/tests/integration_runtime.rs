//! Integration: PJRT runtime over the real AOT artifacts.
//!
//! Requires `make artifacts`. Validates the full python→HLO-text→rust
//! round trip: train step numerics (loss ≈ ln V at init, finite grads),
//! the Pallas add_pair kernel vs the portable reducer, and a short
//! real training loop that must reduce the loss.

use std::sync::Arc;

use nezha::coordinator::collective::{Reducer, RustReducer};
use nezha::runtime::{Engine, ModelRunner, PjrtReducer};
use nezha::util::rng::Pcg;

fn engine() -> Option<Arc<Engine>> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature (xla-backed runtime stubbed)");
        return None;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Engine::new("artifacts").unwrap()))
}

fn synth_tokens(rng: &mut Pcg, n: usize, vocab: usize) -> Vec<i32> {
    // skewed synthetic "language": zipf-ish token draws
    (0..n)
        .map(|_| {
            let u = rng.f64();
            ((u * u * (vocab as f64 - 1.0)) as i32).min(vocab as i32 - 1)
        })
        .collect()
}

#[test]
fn add_pair_kernel_matches_rust_reducer() {
    let Some(engine) = engine() else { return };
    let mut pjrt = PjrtReducer::new(engine).unwrap();
    let mut rust = RustReducer;
    let mut rng = Pcg::new(1);
    // cover: tail-only, one kernel block + tail, multi-block
    for len in [1000usize, 65536, 65536 + 1234, 262144 + 65536 + 7] {
        let mut a: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
        let mut a2 = a.clone();
        pjrt.add_into(&mut a, &b);
        rust.add_into(&mut a2, &b);
        assert_eq!(a, a2, "len {len}");
    }
    assert!(pjrt.kernel_elems > 0, "Pallas kernel never dispatched");
}

#[test]
fn train_step_initial_loss_near_uniform() {
    let Some(engine) = engine() else { return };
    let runner = ModelRunner::new(engine, "tiny").unwrap();
    let params = runner.init_params().unwrap();
    let mut rng = Pcg::new(2);
    let tokens = synth_tokens(&mut rng, runner.batch_elems(), runner.spec.vocab);
    let (loss, grads) = runner.train_step(&params, &tokens).unwrap();
    let expect = (runner.spec.vocab as f32).ln();
    assert!(
        (loss - expect).abs() < 1.5,
        "initial loss {loss}, ln(V) = {expect}"
    );
    assert!(grads.iter().all(|g| g.is_finite()));
    assert!(grads.iter().any(|g| *g != 0.0));
    // padding region must stay zero-gradient
    for g in &grads[runner.spec.n_params..] {
        assert_eq!(*g, 0.0);
    }
}

#[test]
fn sgd_update_moves_params_against_gradient() {
    let Some(engine) = engine() else { return };
    let runner = ModelRunner::new(engine, "tiny").unwrap();
    let n = runner.spec.padded;
    let params = vec![1.0f32; n];
    let grads = vec![0.5f32; n];
    let vel = vec![0.0f32; n];
    let (p2, v2) = runner.sgd_update(&params, &grads, &vel, 0.1, 0.9).unwrap();
    for i in (0..n).step_by(n / 7) {
        assert!((p2[i] - (1.0 - 0.05)).abs() < 1e-6);
        assert!((v2[i] - 0.5).abs() < 1e-6);
    }
}

#[test]
fn short_training_run_decreases_loss() {
    let Some(engine) = engine() else { return };
    let runner = ModelRunner::new(engine, "tiny").unwrap();
    let mut params = runner.init_params().unwrap();
    let mut vel = vec![0.0f32; runner.spec.padded];
    let mut rng = Pcg::new(3);
    let tokens = synth_tokens(&mut rng, runner.batch_elems(), runner.spec.vocab);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..8 {
        let (loss, grads) = runner.train_step(&params, &tokens).unwrap();
        first.get_or_insert(loss);
        last = loss;
        let (p2, v2) = runner.sgd_update(&params, &grads, &vel, 0.05, 0.9).unwrap();
        params = p2;
        vel = v2;
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.3,
        "loss did not decrease: {first} -> {last}"
    );
}
