//! Integration: barrier-free cross-iteration gradient scheduling
//! (DESIGN.md §13) — the {model} × {sched} × {exec} matrix.
//!
//! Every cell must show (a) priority-mode gradients bit-identical to the
//! barrier baseline at every measured iteration, (b) priority modeled
//! iteration time no worse than barrier (strictly better on these
//! comm-bound models), and (c) real overlap: at least one collective in
//! flight across an iteration boundary.

use nezha::config::{Config, Policy};
use nezha::net::cpu_pool::{ExecMode, SchedMode};
use nezha::net::topology::parse_combo;
use nezha::trainer::{CommProfile, DdpSim};

const WARMUP: usize = 3;
const MEASURED: usize = 4;

fn cfg(exec: ExecMode, sched: SchedMode) -> Config {
    Config {
        nodes: 4,
        combo: parse_combo("tcp-tcp").unwrap(),
        policy: Policy::Nezha,
        deterministic: true,
        exec,
        sched,
        ..Config::default()
    }
}

fn sim(model: &str, bs: usize, exec: ExecMode, sched: SchedMode) -> DdpSim {
    let prof = CommProfile::by_name(model).unwrap();
    DdpSim::new(&cfg(exec, sched), prof, 1, bs).unwrap()
}

/// One matrix cell: warmed barrier and priority twins stepped in
/// lockstep. Returns (barrier total us, priority total us).
fn run_cell(model: &str, bs: usize, exec: ExecMode) -> (f64, f64) {
    let mut barrier = sim(model, bs, exec, SchedMode::Barrier);
    let mut priority = sim(model, bs, exec, SchedMode::Priority);
    barrier.warmup(WARMUP).unwrap();
    priority.warmup(WARMUP).unwrap();
    let (mut bt, mut pt) = (0.0, 0.0);
    for it in 0..MEASURED {
        bt += barrier.iter_time_us().unwrap();
        pt += priority.iter_time_us().unwrap();
        assert_eq!(
            barrier.last_fingerprints(),
            priority.last_fingerprints(),
            "{model}/{}: gradients diverged at measured iteration {it}",
            exec.name()
        );
        assert!(!barrier.last_fingerprints().is_empty());
    }
    // the win is overlap, not a different collective sequence: ops were
    // in flight across at least one iteration boundary
    let stats = priority.sched_stats();
    assert!(
        stats.boundary_in_flight_max >= 1,
        "{model}/{}: no op ever crossed a boundary",
        exec.name()
    );
    assert!(stats.cross_boundary_ops >= 1);
    assert!(stats.ops_enqueued > 0);
    assert!(
        priority.drain_queue(),
        "{model}/{}: wire timeline left a stuck op",
        exec.name()
    );
    (bt, pt)
}

#[test]
fn matrix_alexnet_serial() {
    let (bt, pt) = run_cell("alexnet", 32, ExecMode::Serial);
    assert!(pt < bt, "priority {pt} vs barrier {bt}");
}

#[test]
fn matrix_alexnet_parallel() {
    let (bt, pt) = run_cell("alexnet", 32, ExecMode::Parallel);
    assert!(pt < bt, "priority {pt} vs barrier {bt}");
}

#[test]
fn matrix_vgg11_serial() {
    let (bt, pt) = run_cell("vgg11", 64, ExecMode::Serial);
    assert!(pt < bt, "priority {pt} vs barrier {bt}");
}

#[test]
fn matrix_vgg11_parallel() {
    let (bt, pt) = run_cell("vgg11", 64, ExecMode::Parallel);
    assert!(pt < bt, "priority {pt} vs barrier {bt}");
}

#[test]
fn exec_engine_does_not_perturb_modeled_time() {
    // the host-side executor (and its priority-tagged worker drain) is a
    // wall-clock concern only: modeled times and gradients must be
    // bit-identical between serial and parallel execution in BOTH
    // scheduling modes
    for sched in [SchedMode::Barrier, SchedMode::Priority] {
        let mut serial = sim("alexnet", 32, ExecMode::Serial, sched);
        let mut parallel = sim("alexnet", 32, ExecMode::Parallel, sched);
        serial.warmup(WARMUP).unwrap();
        parallel.warmup(WARMUP).unwrap();
        for it in 0..MEASURED {
            let ts = serial.iter_time_us().unwrap();
            let tp = parallel.iter_time_us().unwrap();
            assert_eq!(ts, tp, "{}: exec engines diverged at iter {it}", sched.name());
            assert_eq!(serial.last_fingerprints(), parallel.last_fingerprints());
        }
    }
}

#[test]
fn in_flight_ops_carry_plan_epochs_and_priorities() {
    let mut sim = sim("vgg11", 64, ExecMode::Serial, SchedMode::Priority);
    sim.warmup(WARMUP).unwrap();
    sim.iter_time_us().unwrap();
    let k = sim.profile.ops.len();
    let plan_epoch = sim.plan_epoch();
    let ops = sim.queued_ops();
    assert!(!ops.is_empty(), "boundary pruning must keep the live iteration");
    for op in ops {
        // priority = consumption position of the NEXT forward pass
        assert_eq!(op.priority as usize, k - 1 - op.bucket);
        // ops carry the plan-cache epoch they executed under — never a
        // future one (an intra-iteration replan may bump the epoch after
        // early buckets were already enqueued)
        assert!(op.epoch <= plan_epoch);
        assert!(op.dur_us > 0.0);
    }
    // the last-produced bucket drains first next forward
    assert!(ops.iter().any(|o| o.priority == 0));
    assert!(sim.drain_queue());
}

#[test]
fn compute_bound_stays_bit_identical_and_near_parity() {
    // a synthetic compute-heavy profile: tiny gradients, slow math. Here
    // barrier's overlap credit hides comm completely, while the
    // barrier-free span still exposes the LAST bucket's wire time (its
    // gradient only exists at backward end, and the next forward step 0
    // needs it immediately) — so priority may trail by up to that one
    // bucket's duration, a vanishing fraction of compute. Numerics must
    // match exactly either way.
    let prof = || CommProfile::synthetic("computebound", vec![1 << 16; 4], 50.0);
    let mut barrier = DdpSim::new(
        &cfg(ExecMode::Serial, SchedMode::Barrier),
        prof(),
        1,
        32,
    )
    .unwrap();
    let mut priority = DdpSim::new(
        &cfg(ExecMode::Serial, SchedMode::Priority),
        prof(),
        1,
        32,
    )
    .unwrap();
    barrier.warmup(WARMUP).unwrap();
    priority.warmup(WARMUP).unwrap();
    let (mut bt, mut pt) = (0.0, 0.0);
    for _ in 0..MEASURED {
        bt += barrier.iter_time_us().unwrap();
        pt += priority.iter_time_us().unwrap();
        assert_eq!(barrier.last_fingerprints(), priority.last_fingerprints());
    }
    // near parity: the exposed tail is one tiny bucket per iteration
    assert!(pt <= bt * 1.01, "priority {pt} vs barrier {bt}");
    assert!(priority.drain_queue());
}
