//! Integration: straggler-aware replanning (ROADMAP milestone).
//!
//! A persistent log-normal straggler injected on one rail via the Fabric
//! is invisible to the a-priori α-β model — only measurements see it. The
//! planner's `CorrectedCost` layer must (a) learn the per-round stalls
//! once the Timer warm-up gate opens and switch the straggler rail to a
//! fewer-round schedule, (b) keep allreduce results bit-identical to the
//! seed's fixed dispatch across the switch, and (c) beat the
//! corrections-disabled `planner=static-cost` ablation end-to-end.

use nezha::baselines::FixedShares;
use nezha::config::{Config, PlannerMode, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::coordinator::planner::cost::schedule_rounds;
use nezha::net::topology::{parse_combo, ClusterSpec};
use nezha::util::rng::Pcg;

const ELEMS: usize = 1024;
/// 768 MB modeled ops: big enough that deep chunk pipelines win on the
/// clean model, and a 50% share (384 MB) sits mid-bucket so the size
/// class is stable.
const OP_BYTES: u64 = 768 << 20;
const STALL_US: f64 = 15_000.0;

fn pods_cfg(mode: PlannerMode) -> Config {
    let mut c = Config {
        cluster: ClusterSpec::pods(4),
        nodes: 16,
        combo: parse_combo("tcp-tcp").unwrap(),
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    c.planner = mode;
    c.control.timer_window = 4;
    c.control.replan_error = 0.2;
    c
}

fn op(mr: &mut MultiRail, data: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let mut buf = UnboundBuffer::new(data.to_vec());
    mr.allreduce_scaled(&mut buf, OP_BYTES as f64 / ELEMS as f64)
        .unwrap();
    buf.into_data()
}

fn int_data(seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg::new(seed);
    (0..16)
        .map(|_| (0..ELEMS).map(|_| rng.range(-40, 40) as f32).collect())
        .collect()
}

/// The satellite's core assertion: the straggler rail's schedule switches
/// after warm-up (fewer rail rounds), and every op before, during and
/// after the switch reduces bit-identically to the seed reducer.
#[test]
fn straggler_switches_schedule_after_warmup_bit_identical() {
    let mut mr = MultiRail::new(&pods_cfg(PlannerMode::Auto))
        .unwrap()
        // log-normal stalls (sigma 0.4) around 15 ms per message on rail 0
        .with_straggler(0, STALL_US, 0.4);
    // fixed 50/50 shares isolate the schedule-level response from the
    // Load Balancer's share-level one
    mr.partitioner = Box::new(FixedShares::percent(50, 50));

    // integer-valued payloads sum exactly in f32: equality below is exact
    let expect = |data: &[Vec<f32>]| -> Vec<f32> {
        (0..ELEMS)
            .map(|i| data.iter().map(|d| d[i]).sum())
            .collect()
    };

    let first_data = int_data(1);
    let reduced = op(&mut mr, &first_data);
    let want = expect(&first_data);
    for n in 0..16 {
        assert_eq!(reduced[n], want, "node {n} before warm-up");
    }
    let first = mr.last_plan.clone().unwrap();
    let s_before = first.assignments.iter().find(|a| a.rail == 0).unwrap().schedule;
    let rounds_before = schedule_rounds(s_before, 16);

    // warm up past the Timer window; the replan trigger must fire
    for k in 0..16u64 {
        let data = int_data(100 + k);
        let reduced = op(&mut mr, &data);
        let want = expect(&data);
        for n in 0..16 {
            assert_eq!(reduced[n], want, "node {n} op {k}: numerics drifted");
        }
    }

    let last = mr.last_plan.clone().unwrap();
    let s_after = last.assignments.iter().find(|a| a.rail == 0).unwrap().schedule;
    let rounds_after = schedule_rounds(s_after, 16);
    assert_ne!(s_after, s_before, "planner never switched the straggler rail");
    assert!(
        rounds_after < rounds_before,
        "switch must cut rail rounds: {s_before:?}({rounds_before}) -> {s_after:?}({rounds_after})"
    );
    // the corrected prediction owns the stalls: per-round excess learned
    let share_bytes = OP_BYTES / 2;
    assert!(
        mr.planner.corrections.round_extra_us(0, share_bytes) > 0.5 * STALL_US,
        "round_extra {}",
        mr.planner.corrections.round_extra_us(0, share_bytes)
    );
}

/// Bitwise cross-check against the seed's fixed flat-ring dispatch: same
/// data, same fixed shares — every node's reduced buffer is identical
/// bit-for-bit even while the corrected planner switches schedules
/// (normal-distributed floats, so rounding order matters: this checks
/// true bitwise identity, not just integer sums).
#[test]
fn straggler_run_matches_seed_dispatch_bitwise() {
    let run = |mode: PlannerMode| -> Vec<Vec<Vec<f32>>> {
        let mut mr = MultiRail::new(&pods_cfg(mode))
            .unwrap()
            .with_straggler(0, STALL_US, 0.4);
        mr.partitioner = Box::new(FixedShares::percent(50, 50));
        (0..10u64)
            .map(|k| {
                let mut rng = Pcg::new(500 + k);
                let data: Vec<Vec<f32>> = (0..16)
                    .map(|_| (0..ELEMS).map(|_| rng.normal() as f32).collect())
                    .collect();
                op(&mut mr, &data)
            })
            .collect()
    };
    let auto = run(PlannerMode::Auto);
    let seed = run(PlannerMode::Flat);
    for (k, (a, s)) in auto.iter().zip(&seed).enumerate() {
        for n in 0..16 {
            assert_eq!(a[n], s[n], "op {k} node {n} diverged bitwise");
        }
    }
}

#[test]
fn corrections_beat_static_cost_under_straggler() {
    // The acceptance criterion: with a straggler on one rail of the pods
    // topology, planner=auto (corrections) beats planner=auto without
    // them (static-cost) on end-to-end allreduce time.
    let cluster = ClusterSpec::pods(4);
    let (static_us, _) = nezha::bench::straggler_mode_latency(
        &cluster,
        "tcp-tcp",
        16,
        PlannerMode::StaticCost,
        0,
        STALL_US,
        OP_BYTES,
        25,
        6,
    )
    .unwrap();
    let (auto_us, auto_plan) = nezha::bench::straggler_mode_latency(
        &cluster,
        "tcp-tcp",
        16,
        PlannerMode::Auto,
        0,
        STALL_US,
        OP_BYTES,
        25,
        6,
    )
    .unwrap();
    assert!(
        auto_us < 0.97 * static_us,
        "corrections must win under a straggler: auto {auto_us}us vs static {static_us}us ({auto_plan})"
    );
}

/// The Load Balancer reacts at the share level in parallel: its α table
/// moves data off the straggler rail (Nezha policy, no fixed shares).
#[test]
fn balancer_shares_shift_off_straggler_rail() {
    let mut mr = MultiRail::new(&pods_cfg(PlannerMode::Auto))
        .unwrap()
        .with_straggler(0, STALL_US, 0.0);
    for _ in 0..20 {
        let mut buf = UnboundBuffer::from_fn(16, ELEMS, |n, i| ((n + i) % 7) as f32);
        mr.allreduce_scaled(&mut buf, OP_BYTES as f64 / ELEMS as f64)
            .unwrap();
    }
    let alphas = mr.partitioner.alphas(OP_BYTES).expect("hot class");
    let a0 = alphas.iter().find(|(r, _)| *r == 0).map(|(_, a)| *a).unwrap_or(0.0);
    let a1 = alphas.iter().find(|(r, _)| *r == 1).map(|(_, a)| *a).unwrap_or(0.0);
    assert!(
        a0 < a1,
        "straggler rail should carry less: a0 {a0} vs a1 {a1}"
    );
}
