//! Integration: multi-level hierarchical topologies — the scenario
//! matrix {flat, pods, racked-pods, non-uniform groups} × {planner
//! auto/static-cost/flat} × {exec serial/parallel}, plan validity on
//! every cell, bit-identical serial-vs-parallel numerics, the
//! racked-pods acceptance criterion (auto picks a multi-level cut that
//! beats two-level and flat), per-group rail-affinity enforcement, and
//! the `ClusterSpec::pods` divisibility regression.

use nezha::bench::ablation::{multilevel_sweep, multilevel_sweep_json};
use nezha::config::{Config, PlannerMode, Policy};
use nezha::coordinator::buffer::{UnboundBuffer, Window};
use nezha::coordinator::multirail::MultiRail;
use nezha::coordinator::planner::Schedule;
use nezha::net::cpu_pool::ExecMode;
use nezha::net::topology::{parse_combo, ClusterSpec};
use nezha::util::error::Error;

const LEN: usize = 2048;

fn scenarios() -> Vec<(&'static str, ClusterSpec, usize)> {
    vec![
        ("flat", ClusterSpec::local(), 8),
        ("pods", ClusterSpec::pods(4), 16),
        ("racked-pods", ClusterSpec::racked_pods(4, 16), 32),
        ("non-uniform", ClusterSpec::grouped(vec![2, 6, 4, 4]), 16),
    ]
}

fn cfg(cluster: ClusterSpec, nodes: usize, mode: PlannerMode, exec: ExecMode) -> Config {
    let mut c = Config {
        cluster,
        nodes,
        combo: parse_combo("tcp-tcp").unwrap(),
        policy: Policy::Nezha,
        // jitter ON: cell identity must hold for sampled times, not just
        // the deterministic model (fixed seed keeps runs reproducible)
        deterministic: false,
        seed: 77,
        exec,
        ..Config::default()
    };
    c.planner = mode;
    c
}

fn fill(salt: usize) -> impl Fn(usize, usize) -> f32 + Copy {
    move |n: usize, i: usize| ((n * 7 + i * 3 + salt) % 13) as f32
}

fn check_reduced(buf: &UnboundBuffer, nodes: usize, salt: usize, tag: &str) {
    let f = fill(salt);
    for n in 0..nodes {
        for i in (0..LEN).step_by(251) {
            let expect: f32 = (0..nodes).map(|m| f(m, i)).sum();
            assert_eq!(buf.node(n)[i], expect, "{tag}: node {n} elem {i}");
        }
    }
}

/// The matrix: every topology × planner mode runs under BOTH executors
/// with identical results — modeled times, per-rail shares and payload
/// bits — and every planner-scheduled cell emits a valid plan (windows
/// partition the op exactly; shares form a distribution).
#[test]
fn scenario_matrix_plans_valid_and_executors_bit_identical() {
    let modes = [PlannerMode::Auto, PlannerMode::StaticCost, PlannerMode::Flat];
    for (scen, cluster, nodes) in scenarios() {
        for mode in modes {
            let tag = format!("{scen}/{}", mode.name());
            let mut serial =
                MultiRail::new(&cfg(cluster.clone(), nodes, mode, ExecMode::Serial)).unwrap();
            let mut parallel =
                MultiRail::new(&cfg(cluster.clone(), nodes, mode, ExecMode::Parallel)).unwrap();
            // hot large ops (multi-rail), then a small op (cold single-rail)
            for (op, bytes) in [(0u32, 64u64 << 20), (1, 256 << 20), (2, 1 << 20)] {
                let salt = op as usize + nodes;
                let mut sb = UnboundBuffer::from_fn(nodes, LEN, fill(salt));
                let mut pb = UnboundBuffer::from_fn(nodes, LEN, fill(salt));
                let elem_bytes = bytes as f64 / LEN as f64;
                let rs = serial.allreduce_scaled(&mut sb, elem_bytes).unwrap();
                let rp = parallel.allreduce_scaled(&mut pb, elem_bytes).unwrap();
                assert_eq!(rs.total_us, rp.total_us, "{tag} op {op}: modeled time diverged");
                assert_eq!(rs.per_rail.len(), rp.per_rail.len(), "{tag} op {op}");
                for (a, b) in rs.per_rail.iter().zip(&rp.per_rail) {
                    assert_eq!(a.rail, b.rail, "{tag} op {op}");
                    assert_eq!(a.bytes, b.bytes, "{tag} op {op} rail {}", a.rail);
                    assert_eq!(a.time_us, b.time_us, "{tag} op {op} rail {}", a.rail);
                }
                for n in 0..nodes {
                    assert_eq!(sb.node(n), pb.node(n), "{tag} op {op} node {n}: numerics diverged");
                }
                check_reduced(&pb, nodes, salt, &tag);
                // plan validity on planner-scheduled cells (forced flat
                // dispatch records no plan, by design)
                if mode == PlannerMode::Flat {
                    assert!(serial.last_plan.is_none(), "{tag}");
                } else {
                    let plan = serial.last_plan.as_ref().unwrap_or_else(|| {
                        panic!("{tag} op {op}: planner-scheduled op must record a plan")
                    });
                    assert!(plan.conserves(Window::new(0, LEN)), "{tag} op {op}: {plan:?}");
                    assert!(plan.active_rails() >= 1, "{tag} op {op}");
                    let total: u64 = rs.per_rail.iter().map(|s| s.bytes).sum();
                    assert_eq!(total, rs.bytes, "{tag} op {op}: share bytes must cover the op");
                }
            }
        }
    }
}

/// Acceptance criterion: on the racked-pods cluster the auto planner
/// selects a multi-level schedule for large payloads whose modeled
/// completion beats both the two-level (rack-cut-only) planner and the
/// flat dispatch, as recorded in the ablation sweep/JSON artifact — while
/// one-level configurations keep emitting plain two-level plans.
#[test]
fn racked_pods_multi_level_beats_two_level_and_flat() {
    // executed-plan check: the large-payload schedule is a depth-2 cut
    let mut mr = MultiRail::new(&cfg(
        ClusterSpec::racked_pods(4, 16),
        32,
        PlannerMode::Auto,
        ExecMode::Serial,
    ))
    .unwrap();
    let bytes = 256u64 << 20;
    for _ in 0..3 {
        let mut buf = UnboundBuffer::from_fn(32, LEN, fill(1));
        mr.allreduce_scaled(&mut buf, bytes as f64 / LEN as f64).unwrap();
    }
    let plan = mr.last_plan.as_ref().unwrap();
    assert!(
        plan.assignments
            .iter()
            .filter(|a| a.bytes > 0)
            .any(|a| matches!(a.schedule, Schedule::MultiLevel { depth: 2, groups: 2, .. })),
        "expected a depth-2 multi-level assignment, got {}",
        plan.label()
    );

    // sweep check: the ablation JSON records the three-way comparison
    let rows = multilevel_sweep().unwrap();
    let large = rows.last().unwrap();
    assert_eq!(large.bytes, 256 << 20);
    assert!(
        large.multi_us < large.two_us && large.multi_us < large.flat_us,
        "multi-level {} must beat two-level {} and flat {}",
        large.multi_us,
        large.two_us,
        large.flat_us
    );
    assert!(large.multi_plan.contains("multi-level"), "{}", large.multi_plan);
    // the rack-only baseline stays in the two-level family (pre-PR space)
    assert!(large.two_plan.contains("two-level"), "{}", large.two_plan);
    let j = multilevel_sweep_json(&rows);
    assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("multilevel_topology"));
    assert_eq!(
        j.get("results").map(|v| match v {
            nezha::util::json::Json::Arr(a) => a.len(),
            _ => 0,
        }),
        Some(rows.len())
    );
}

/// One-level trees are the pre-PR planner: a pods cluster keeps producing
/// plain two-level plans (never multi-level), preserving seed behaviour.
#[test]
fn one_level_cluster_keeps_two_level_plans() {
    let mut mr = MultiRail::new(&cfg(
        ClusterSpec::pods(4),
        16,
        PlannerMode::Auto,
        ExecMode::Serial,
    ))
    .unwrap();
    let bytes = 64u64 << 20;
    for _ in 0..3 {
        let mut buf = UnboundBuffer::from_fn(16, LEN, fill(2));
        mr.allreduce_scaled(&mut buf, bytes as f64 / LEN as f64).unwrap();
    }
    let plan = mr.last_plan.as_ref().unwrap();
    assert!(
        plan.assignments
            .iter()
            .filter(|a| a.bytes > 0)
            .any(|a| matches!(a.schedule, Schedule::TwoLevel { group: 4, .. })),
        "{}",
        plan.label()
    );
    for a in &plan.assignments {
        assert!(
            !matches!(a.schedule, Schedule::MultiLevel { .. }),
            "one-level tree must never emit multi-level: {}",
            plan.label()
        );
    }
}

/// Per-group rail affinity: rails excluded by any group's mask never
/// carry payload, in planning or execution.
#[test]
fn affinity_masks_keep_excluded_rails_idle() {
    // 4 pod groups, every group allows rail 0 only
    let cluster = ClusterSpec::pods(4).with_affinity(0, vec![0b01; 4]);
    let mut mr =
        MultiRail::new(&cfg(cluster, 16, PlannerMode::Auto, ExecMode::Serial)).unwrap();
    let bytes = 64u64 << 20;
    for op in 0..6 {
        let mut buf = UnboundBuffer::from_fn(16, LEN, fill(op));
        let rep = mr.allreduce_scaled(&mut buf, bytes as f64 / LEN as f64).unwrap();
        for s in &rep.per_rail {
            if s.rail == 1 {
                assert_eq!(s.bytes, 0, "op {op}: affinity-excluded rail carried payload");
            }
        }
        assert!(rep.per_rail.iter().any(|s| s.rail == 0 && s.bytes > 0), "op {op}");
        check_reduced(&buf, 16, op, "affinity");
    }
    // the preview path honours the mask too
    let plan = mr.plan_for(bytes).unwrap();
    assert!(plan.rails().iter().all(|&r| r == 0), "{plan:?}");
}

/// Unsatisfiable or malformed affinity masks are construction errors.
#[test]
fn bad_affinity_masks_are_rejected_at_construction() {
    // empty intersection across groups
    let disjoint = ClusterSpec::pods(4).with_affinity(0, vec![0b01, 0b10, 0b01, 0b10]);
    let err = MultiRail::new(&cfg(disjoint, 16, PlannerMode::Auto, ExecMode::Serial))
        .unwrap_err();
    assert!(matches!(err, Error::Topology(_)), "{err:?}");
    // a mask naming only nonexistent rails
    let ghost = ClusterSpec::pods(4).with_affinity(0, vec![0b1000; 4]);
    assert!(MultiRail::new(&cfg(ghost, 16, PlannerMode::Auto, ExecMode::Serial)).is_err());
}

/// Regression: `ClusterSpec::pods` used to silently accept group sizes
/// that don't divide the node count; the coordinator now rejects them
/// with a precise `Error::Topology` at construction.
#[test]
fn pods_non_dividing_group_is_a_construction_error() {
    let err = MultiRail::new(&cfg(ClusterSpec::pods(4), 6, PlannerMode::Auto, ExecMode::Serial))
        .unwrap_err();
    match err {
        Error::Topology(msg) => assert!(msg.contains("does not divide"), "{msg}"),
        other => panic!("expected Error::Topology, got {other:?}"),
    }
    // dividing node counts construct fine
    assert!(
        MultiRail::new(&cfg(ClusterSpec::pods(4), 16, PlannerMode::Auto, ExecMode::Serial))
            .is_ok()
    );
    // racked-pods with a node count that splits a pod is rejected too
    assert!(MultiRail::new(&cfg(
        ClusterSpec::racked_pods(4, 16),
        24,
        PlannerMode::Auto,
        ExecMode::Serial
    ))
    .is_err());
}
