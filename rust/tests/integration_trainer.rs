//! Integration: trainer stack — DDP speed shapes (Figs. 12/16/17), vTrain
//! GPT replay (Fig. 18), and the real e2e loop (artifacts-gated).

use nezha::config::{Config, Policy};
use nezha::net::topology::parse_combo;
use nezha::trainer::{train_e2e, CommProfile, DdpSim, E2EConfig, GptModel, VtrainSim};

fn cfg(combo: &str, nodes: usize, policy: Policy) -> Config {
    Config {
        nodes,
        combo: parse_combo(combo).unwrap(),
        policy,
        deterministic: true,
        ..Config::default()
    }
}

fn speed(combo: &str, nodes: usize, policy: Policy, model: &str, gpus: usize, bs: usize) -> f64 {
    let prof = CommProfile::by_name(model).unwrap();
    let mut sim = DdpSim::new(&cfg(combo, nodes, policy), prof, gpus, bs).unwrap();
    sim.warmup(5).unwrap();
    sim.samples_per_sec_per_node().unwrap()
}

#[test]
fn fig12_shape_dual_tcp_beats_gloo_more_at_8_nodes() {
    // paper: VGG-11 bs64 TCP-TCP over Gloo TCP: +19.9% @4 nodes, +50.4% @8
    let g4 = speed("tcp", 4, Policy::SingleRail, "vgg11", 1, 64);
    let n4 = speed("tcp-tcp", 4, Policy::Nezha, "vgg11", 1, 64);
    let g8 = speed("tcp", 8, Policy::SingleRail, "vgg11", 1, 64);
    let n8 = speed("tcp-tcp", 8, Policy::Nezha, "vgg11", 1, 64);
    let imp4 = n4 / g4 - 1.0;
    let imp8 = n8 / g8 - 1.0;
    assert!(imp4 > 0.15, "4-node improvement {imp4}");
    assert!(imp8 > 0.15, "8-node improvement {imp8}");
    // Deviation note (EXPERIMENTS.md): the paper reports the gain GROWING
    // 19.9% -> 50.4%; in our calibration communication dominates at both
    // scales, so the gain is roughly flat. We assert it stays in a band.
    assert!((imp8 - imp4).abs() < 0.3, "{imp4} vs {imp8}");
}

#[test]
fn fig12_shape_rdma_combos_gain_less() {
    // paper: TCP-GLEX gains over GLEX (~11%) are much smaller than
    // TCP-TCP's over TCP (~50-70%) because rho is larger
    let tcp_gain = speed("tcp-tcp", 8, Policy::Nezha, "alexnet", 1, 32)
        / speed("tcp", 8, Policy::SingleRail, "alexnet", 1, 32);
    let glex_gain = speed("tcp-glex", 8, Policy::Nezha, "alexnet", 1, 32)
        / speed("glex", 8, Policy::SingleRail, "alexnet", 1, 32);
    assert!(glex_gain < tcp_gain, "glex {glex_gain} vs tcp {tcp_gain}");
    assert!(glex_gain > 0.9, "multi-rail must not cripple GLEX: {glex_gain}");
}

#[test]
fn fig16_shape_gpu_and_nic_scaling_compose() {
    let g1n1 = speed("tcp", 4, Policy::SingleRail, "alexnet", 1, 32);
    let g1n2 = speed("tcp-tcp", 4, Policy::Nezha, "alexnet", 1, 32);
    let g2n1 = speed("tcp", 4, Policy::SingleRail, "alexnet", 2, 32);
    let g2n2 = speed("tcp-tcp", 4, Policy::Nezha, "alexnet", 2, 32);
    assert!(g1n2 > 1.15 * g1n1, "N2 gain: {}", g1n2 / g1n1);
    assert!(g2n1 > 1.4 * g1n1, "G2 gain: {}", g2n1 / g1n1);
    assert!(g2n2 > g2n1 && g2n2 > g1n2, "G2N2 must dominate");
    // paper: G2N2 ≈ 2.0-2.6x
    let r = g2n2 / g1n1;
    assert!(r > 1.8 && r < 3.5, "G2N2 ratio {r}");
}

#[test]
fn fig17_shape_ratio_grows_with_nodes() {
    let ratio = |nodes| {
        speed("tcp-tcp", nodes, Policy::Nezha, "alexnet", 1, 32)
            / speed("tcp", nodes, Policy::SingleRail, "alexnet", 1, 32)
    };
    let r4 = ratio(4);
    let r16 = ratio(16);
    // paper band: 1.51x–1.54x across 4..16 nodes (roughly flat). Our model
    // stays in a similar band; see EXPERIMENTS.md for the deviation note.
    assert!(r4 > 1.25 && r4 < 1.8, "band check r4 = {r4}");
    assert!(r16 > 1.25 && r16 < 1.8, "band check r16 = {r16}");
}

#[test]
fn fig18_shape_gpt_speedup_grows_and_hits_paper_band() {
    let iter = |nodes, policy| {
        VtrainSim::new(GptModel::Gpt2_7B, nodes, policy, None)
            .unwrap()
            .iteration_time_s()
            .unwrap()
    };
    let s16 = iter(16, Policy::SingleRail) / iter(16, Policy::Nezha);
    let s128 = iter(128, Policy::SingleRail) / iter(128, Policy::Nezha);
    assert!(s128 > s16, "efficiency gap must widen: {s16} -> {s128}");
    // paper: 2.38x at 128 nodes (Ring)
    assert!(s128 > 1.8 && s128 < 3.2, "128-node speedup {s128}");
}

#[test]
fn gpt30b_splits_oversized_packets() {
    // >1GB gradients must split into 256MB packets and still complete
    let mut sim = VtrainSim::new(GptModel::Gpt30B, 32, Policy::Nezha, None).unwrap();
    let t = sim.iteration_time_s().unwrap();
    assert!(t.is_finite() && t > 0.0);
}

#[test]
fn e2e_training_reduces_loss_through_multirail() {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature");
        return;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let c = cfg("tcp-tcp", 4, Policy::Nezha);
    let e2e = E2EConfig {
        model: "tiny".into(),
        steps: 10,
        lr: 0.05,
        momentum: 0.9,
        bucket_elems: 200_000, // force multiple fusion buckets
        log_every: 0,
        use_pjrt_reducer: true,
        seed: 3,
    };
    let logs = train_e2e(&c, &e2e).unwrap();
    assert_eq!(logs.len(), 10);
    let first = logs.first().unwrap().loss;
    let last = logs.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last}");
    assert!(logs.iter().all(|l| l.comm_us > 0.0));
}

#[test]
fn e2e_pjrt_and_rust_reducers_agree() {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature");
        return;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let c = cfg("tcp-tcp", 2, Policy::Nezha);
    let run = |use_pjrt: bool| {
        let e2e = E2EConfig {
            model: "tiny".into(),
            steps: 3,
            lr: 0.05,
            momentum: 0.9,
            bucket_elems: 1 << 30,
            log_every: 0,
            use_pjrt_reducer: use_pjrt,
            seed: 5,
        };
        train_e2e(&c, &e2e).unwrap()
    };
    let a = run(true);
    let b = run(false);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.loss, y.loss, "reducer backends diverged at step {}", x.step);
    }
}
