//! Property-based tests over the coordinator invariants, driven by a
//! seeded PCG fuzzer (proptest is unavailable offline; this is the same
//! generate-and-check loop with explicit seeds, so failures reproduce).

use nezha::coordinator::buffer::{UnboundBuffer, Window};
use nezha::coordinator::collective::ring::ring_numerics;
use nezha::coordinator::collective::{Reducer, RustReducer};
use nezha::coordinator::control::load_balancer::LoadBalancer;
use nezha::coordinator::control::Timer;
use nezha::coordinator::planner::{cost, pipeline, Planner, Schedule};
use nezha::config::ControlConfig;
use nezha::net::cpu_pool::CpuPool;
use nezha::net::protocol::ProtoKind;
use nezha::net::simnet::Fabric;
use nezha::net::topology::{ClusterSpec, IntraLink, TopoLevel, TopologyTree};
use nezha::util::json::Json;
use nezha::util::rng::Pcg;

const CASES: usize = 60;

/// Property: split_fractions always partitions the window exactly —
/// contiguous, non-overlapping, total length preserved.
#[test]
fn prop_window_split_partitions_exactly() {
    let mut rng = Pcg::new(1001);
    for case in 0..CASES {
        let len = 1 + rng.below(100_000) as usize;
        let off = rng.below(1000) as usize;
        let k = 1 + rng.below(6) as usize;
        let mut fracs: Vec<f64> = (0..k).map(|_| rng.f64().max(1e-6)).collect();
        let s: f64 = fracs.iter().sum();
        for f in &mut fracs {
            *f /= s;
        }
        let w = Window::new(off, len);
        let parts = w.split_fractions(&fracs);
        assert_eq!(parts.len(), k, "case {case}");
        let mut cursor = off;
        for p in &parts {
            assert_eq!(p.offset, cursor, "case {case}: gap/overlap");
            cursor = p.end();
        }
        assert_eq!(cursor, w.end(), "case {case}: length not preserved");
    }
}

/// Property: ring allreduce numerics == per-element sum over nodes, for
/// random node counts, lengths and windows.
#[test]
fn prop_ring_numerics_equals_nway_sum() {
    let mut rng = Pcg::new(1002);
    for case in 0..CASES {
        let nodes = 2 + rng.below(7) as usize;
        let len = 1 + rng.below(5000) as usize;
        let data: Vec<Vec<f32>> = (0..nodes)
            .map(|_| (0..len).map(|_| rng.range(-64, 64) as f32 * 0.5).collect())
            .collect();
        let expect: Vec<f32> = (0..len).map(|i| data.iter().map(|d| d[i]).sum()).collect();
        let mut buf = UnboundBuffer::new(data);
        // random sub-window
        let wo = rng.below(len as u64) as usize;
        let wl = 1 + rng.below((len - wo) as u64) as usize;
        let w = Window::new(wo, wl);
        ring_numerics(&mut buf, w, &mut RustReducer);
        for n in 0..nodes {
            for i in wo..wo + wl {
                assert_eq!(buf.node(n)[i], expect[i], "case {case} node {n} elem {i}");
            }
        }
    }
}

/// Property: reducer n-way fold is order-independent for integral f32
/// values (exact adds, no rounding).
#[test]
fn prop_reduce_order_independent_for_integers() {
    let mut rng = Pcg::new(1003);
    for _ in 0..CASES {
        let n = 2 + rng.below(6) as usize;
        let len = 1 + rng.below(3000) as usize;
        let srcs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.range(-100, 100) as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut fwd = vec![0.0f32; len];
        RustReducer.reduce_n(&mut fwd, &refs);
        let rev_refs: Vec<&[f32]> = srcs.iter().rev().map(|v| v.as_slice()).collect();
        let mut rev = vec![0.0f32; len];
        RustReducer.reduce_n(&mut rev, &rev_refs);
        assert_eq!(fwd, rev);
    }
}

/// Property: Load Balancer plans always produce normalized, non-negative
/// shares over healthy rails only, for random sizes and feedback.
#[test]
fn prop_balancer_shares_valid_under_random_feedback() {
    let mut rng = Pcg::new(1004);
    for case in 0..CASES {
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Glex])
            .unwrap();
        let fab = Fabric::new(4, rails, CpuPool::default(), case as u64).deterministic();
        let timer = Timer::new(10);
        let mut lb = LoadBalancer::new(ControlConfig::default());
        for _ in 0..30 {
            let bytes = 1u64 << (10 + rng.below(17));
            let plan = lb.plan(&fab, &timer, &[0, 1], bytes);
            match &plan {
                nezha::coordinator::control::Plan::Cold { rail } => {
                    assert!(*rail < 2);
                }
                nezha::coordinator::control::Plan::Hot { shares } => {
                    let sum: f64 = shares.iter().map(|(_, a)| a).sum();
                    assert!((sum - 1.0).abs() < 1e-6, "case {case}: sum {sum}");
                    assert!(shares.iter().all(|(r, a)| *r < 2 && *a >= 0.0));
                }
            }
            // random (possibly nonsense) feedback must never corrupt state
            let t0 = rng.range_f64(1.0, 1e6);
            let t1 = rng.range_f64(1.0, 1e6);
            lb.feedback(&fab, bytes, &[(0, bytes / 2, t0), (1, bytes / 2, t1)]);
        }
    }
}

/// Property: Timer window averages equal the arithmetic mean of the
/// recorded window, for random windows and sequences.
#[test]
fn prop_timer_window_average() {
    let mut rng = Pcg::new(1005);
    for _ in 0..CASES {
        let window = 1 + rng.below(20) as usize;
        let mut t = Timer::new(window);
        let xs: Vec<f64> = (0..window).map(|_| rng.range_f64(1.0, 1e5)).collect();
        for &x in &xs {
            t.record(0, 4096, x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / window as f64;
        let got = t.cost(0, 4096).unwrap();
        assert!((got - mean).abs() / mean < 1e-9);
    }
}

/// Property: JSON emit→parse round-trips arbitrary trees.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.range(-100000, 100000) as f64) / 8.0),
            3 => {
                let len = rng.below(12) as usize;
                let mut s: String = (0..len)
                    .map(|_| char::from_u32(32 + rng.below(90) as u32).unwrap_or('x'))
                    .collect();
                let extra = rng.below(3) as usize;
                s.extend("\"\\\n".chars().take(extra));
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Pcg::new(1006);
    for case in 0..CASES {
        let j = random_json(&mut rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e} on {text}"));
        assert_eq!(back, j, "case {case}");
    }
}

/// Property: fabric timing is monotone in payload size on every protocol
/// (no negative or inverted latencies anywhere in the model).
#[test]
fn prop_fabric_monotone_in_size() {
    let mut rng = Pcg::new(1007);
    for kind in [ProtoKind::Tcp, ProtoKind::Sharp, ProtoKind::Glex] {
        let rails = ClusterSpec::local().build_rails(&[kind]).unwrap();
        let mut fab = Fabric::new(4, rails, CpuPool::default(), 9).deterministic();
        for _ in 0..CASES {
            let a = rng.range_f64(1.0, 1e8);
            let b = a * rng.range_f64(1.0, 10.0);
            let ta = fab.transfer(0, a).unwrap();
            let tb = fab.transfer(0, b).unwrap();
            assert!(tb >= ta, "{kind:?}: T({b})={tb} < T({a})={ta}");
            assert!(ta > 0.0);
        }
    }
}

/// Property: every CollectivePlan conserves bytes (its windows partition
/// the op window exactly, shares form a distribution) and covers exactly
/// the healthy rails it claims, for random combos, node counts, groupings
/// and share splits.
#[test]
fn prop_collective_plan_conserves_bytes_and_claimed_rails() {
    let combos: [&[ProtoKind]; 3] = [
        &[ProtoKind::Tcp, ProtoKind::Tcp],
        &[ProtoKind::Tcp, ProtoKind::Glex],
        &[ProtoKind::Tcp, ProtoKind::Sharp],
    ];
    let mut rng = Pcg::new(2001);
    for case in 0..CASES {
        let combo = combos[rng.below(3) as usize];
        let nodes = [2usize, 4, 8, 16][rng.below(4) as usize];
        let group = [1usize, 2, 4][rng.below(3) as usize];
        let rails = ClusterSpec::local().build_rails(combo).unwrap();
        let fab = Fabric::new(nodes, rails, CpuPool::default(), case as u64).deterministic();
        let mut planner = Planner::new(if group > 1 {
            Some(IntraLink { group_size: group, bw_mbps: 5000.0, setup_us: 15.0 })
        } else {
            None
        });
        // random normalized shares over the two rails (one may be zero)
        let a = rng.f64();
        let shares = vec![(0usize, a), (1usize, 1.0 - a)];
        let bytes = 1u64 << (10 + rng.below(19)); // 1KB..256MB
        let plan = planner.plan(&fab, &Timer::new(100), &shares, bytes);
        let full = Window::new(rng.below(512) as usize, 1 + rng.below(1 << 20) as usize);
        assert!(plan.conserves(full), "case {case}: {plan:?}");
        assert_eq!(plan.rails(), vec![0, 1], "case {case}");
        // per-rail byte split matches the shares (within rounding)
        let total: u64 = plan.assignments.iter().map(|p| p.bytes).sum();
        assert!(
            (total as f64 - bytes as f64).abs() <= 2.0,
            "case {case}: {total} vs {bytes}"
        );
        // predicted time is positive whenever payload is
        assert!(plan.predicted_us > 0.0, "case {case}");
        // two-level schedules only appear with a valid grouping
        for p in &plan.assignments {
            if let Schedule::TwoLevel { group: g, .. } = p.schedule {
                assert!(g > 1 && nodes % g == 0 && nodes / g >= 2, "case {case}: {p:?}");
            }
        }
    }
}

/// Property: hierarchical two-level cost collapses exactly to the flat
/// ring on single-node-per-group topologies, for random sizes and node
/// counts — and the planner never emits a TwoLevel schedule there.
#[test]
fn prop_hierarchical_reduces_to_flat_ring_on_degenerate_groups() {
    let mut rng = Pcg::new(2002);
    let g1 = IntraLink { group_size: 1, bw_mbps: 5000.0, setup_us: 15.0 };
    for case in 0..CASES {
        let nodes = 2 + rng.below(15) as usize;
        let rails = ClusterSpec::local().build_rails(&[ProtoKind::Tcp]).unwrap();
        let fab = Fabric::new(nodes, rails, CpuPool::default(), case as u64).deterministic();
        let bytes = rng.range_f64(1024.0, 2.68e8);
        assert_eq!(
            cost::two_level_us(&fab, 0, bytes, nodes, &g1, 1),
            cost::flat_ring_us(&fab, 0, bytes, nodes),
            "case {case}: degenerate two-level must equal flat ring"
        );
        assert_eq!(cost::intra_phase_us(&g1, bytes), 0.0);
        let planner = Planner::new(Some(g1.clone()));
        let (s, _) = planner.schedule_for(&fab, &Timer::new(100), 0, bytes);
        assert!(
            !matches!(s, Schedule::TwoLevel { .. }),
            "case {case}: degenerate grouping emitted {s:?}"
        );
    }
    // the schedule normalizer agrees
    assert_eq!(
        Schedule::TwoLevel { group: 1, chunks: 1 }.normalized(),
        Schedule::FlatRing
    );
}

/// Property: `CorrectedCost` with zero observations equals the pure α-β
/// model EXACTLY (bit-for-bit), for arbitrary classes, rounds and model
/// costs — corrections must be invisible until data exists.
#[test]
fn prop_corrected_cost_zero_observations_is_identity() {
    let mut rng = Pcg::new(3001);
    let c = cost::CorrectedCost::new();
    for _ in 0..CASES {
        let rail = rng.below(8) as usize;
        let bytes = 1u64 << (6 + rng.below(24));
        let rounds = 1 + rng.below(64) as usize;
        let model = rng.range_f64(1e-3, 1e9);
        assert_eq!(c.corrected_us(rail, bytes, rounds, model), model);
    }
}

/// Property: corrections never change *how much* a rail carries — planner
/// invariant 1. For random share splits and arbitrary (even hostile)
/// observation histories, the corrected plan's shares, windows and
/// per-rail byte split are identical to the uncorrected plan's.
#[test]
fn prop_corrections_preserve_shares() {
    let mut rng = Pcg::new(3002);
    for case in 0..CASES {
        let nodes = [2usize, 4, 8, 16][rng.below(4) as usize];
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Glex])
            .unwrap();
        let fab = Fabric::new(nodes, rails, CpuPool::default(), case as u64).deterministic();
        let mut timer = Timer::new(1); // every class warms instantly
        let mut planner = Planner::new(None);
        let bytes = 1u64 << (12 + rng.below(17));
        let a = rng.f64();
        let shares = vec![(0usize, a), (1usize, 1.0 - a)];
        let clean = planner.plan(&fab, &Timer::new(100), &shares, bytes);
        // hostile feedback: random measurements, warm both classes
        for &(rail, share) in &shares {
            let rail_bytes = (bytes as f64 * share) as u64;
            for _ in 0..5 {
                let measured = rng.range_f64(1.0, 1e7);
                planner.observe(rail, rail_bytes, 1 + rng.below(40) as usize, 1_000.0, 1_000.0, measured);
                timer.record(rail, rail_bytes, measured);
            }
        }
        let corrected = planner.plan(&fab, &timer, &shares, bytes);
        let full = Window::new(0, 1 + rng.below(1 << 18) as usize);
        assert!(corrected.conserves(full), "case {case}");
        assert_eq!(clean.rails(), corrected.rails(), "case {case}");
        for (x, y) in clean.assignments.iter().zip(&corrected.assignments) {
            assert_eq!(x.share, y.share, "case {case}: share changed");
            assert_eq!(x.bytes, y.bytes, "case {case}: byte split changed");
        }
        assert_eq!(clean.windows(full), corrected.windows(full), "case {case}");
    }
}

/// Property: monotonicity — a rail whose measurements are uniformly
/// slower (scaled by k ≥ 1) never gets a LOWER corrected cost than the
/// same rail with the unscaled measurements, for any candidate.
#[test]
fn prop_corrected_cost_monotone_in_measured_slowdown() {
    let mut rng = Pcg::new(3003);
    for case in 0..CASES {
        let mut base = cost::CorrectedCost::new();
        let mut slow = cost::CorrectedCost::new();
        let bytes = 1u64 << (10 + rng.below(18));
        let k = rng.range_f64(1.0, 4.0);
        let n_obs = 1 + rng.below(20);
        let obs_rounds = 1 + rng.below(40) as usize;
        let model = rng.range_f64(10.0, 1e6);
        for _ in 0..n_obs {
            let measured = model * rng.range_f64(0.5, 3.0);
            base.observe(0, bytes, obs_rounds, model, model, measured);
            slow.observe(0, bytes, obs_rounds, model, model, measured * k);
        }
        for _ in 0..8 {
            let cand_rounds = 1 + rng.below(40) as usize;
            let cand_model = rng.range_f64(10.0, 1e6);
            let tb = base.corrected_us(0, bytes, cand_rounds, cand_model);
            let ts = slow.corrected_us(0, bytes, cand_rounds, cand_model);
            assert!(
                ts >= tb - 1e-9,
                "case {case}: slower rail got cheaper ({ts} < {tb}, k={k})"
            );
        }
    }
}

/// Property: `TopologyTree` validation invariants — randomly built
/// well-nested trees (uniform and explicit levels, optional affinity)
/// always validate; breaking any single invariant (non-dividing uniform
/// size, explicit sizes not summing, non-nesting boundary, non-coarsening
/// level, zero affinity mask, mask-count mismatch, disjoint per-group
/// masks) is rejected with `Error::Topology`.
#[test]
fn prop_topology_tree_validation_invariants() {
    use nezha::util::error::Error;
    let mut rng = Pcg::new(6001);
    for case in 0..CASES {
        // nested uniform sizes: g0 | g1 | nodes, strictly increasing
        let g0 = [2usize, 4][rng.below(2) as usize];
        let mult = 2 + rng.below(3) as usize; // g1 = g0 * (2..=4)
        let g1 = g0 * mult;
        let pods = 2 + rng.below(3) as usize;
        let nodes = g1 * pods;
        let n_rails = 2 + rng.below(3) as usize;
        let mut tree = TopologyTree {
            levels: vec![
                TopoLevel::uniform("rack", g0, 5000.0, 8.0),
                TopoLevel::uniform("pod", g1, 2000.0, 12.0),
            ],
        };
        assert!(tree.validate(nodes, n_rails).is_ok(), "case {case}: valid tree rejected");
        assert_eq!(tree.group_count(0, nodes), nodes / g0, "case {case}");
        assert_eq!(tree.max_subgroups(1, nodes), mult, "case {case}");
        assert!(tree.valid_cut_depth(2, nodes), "case {case}");

        // valid affinity: every group allows rail 0 (plus random extras)
        let groups1 = nodes / g1;
        let masks: Vec<u64> = (0..groups1)
            .map(|_| 0b1 | (rng.below(1 << n_rails as u64) & ((1 << n_rails as u64) - 1)))
            .collect();
        tree.levels[1].affinity = Some(masks);
        assert!(tree.validate(nodes, n_rails).is_ok(), "case {case}: valid affinity rejected");

        // each single-invariant break must be rejected
        let reject = |t: &TopologyTree, what: &str| {
            match t.validate(nodes, n_rails) {
                Err(Error::Topology(_)) => {}
                other => panic!("case {case}: {what} not rejected ({other:?})"),
            }
        };
        // (a) uniform size that doesn't divide the node count
        let mut t = tree.clone();
        t.levels[0] = TopoLevel::uniform("rack", g0 + 1, 5000.0, 8.0);
        if nodes % (g0 + 1) != 0 {
            reject(&t, "non-dividing uniform size");
        }
        // (b) explicit sizes that don't cover all nodes
        let mut t = tree.clone();
        t.levels[0] = TopoLevel::explicit("rack", vec![g0; nodes / g0 - 1], 5000.0, 8.0);
        reject(&t, "explicit sizes not summing to the node count");
        // (c) an outer level that splits inner groups
        let mut t = tree.clone();
        t.levels[1] = TopoLevel::uniform("pod", g1 + g0 / 2, 2000.0, 12.0);
        reject(&t, "boundary splitting an inner group");
        // (d) a non-coarsening repeat level
        let mut t = tree.clone();
        t.levels[1] = TopoLevel::uniform("pod", g0, 2000.0, 12.0);
        reject(&t, "non-coarsening level");
        // (e) a zero affinity mask (empties that group's rail set)
        let mut t = tree.clone();
        let mut masks = vec![u64::MAX; groups1];
        masks[rng.below(groups1 as u64) as usize] = 0;
        t.levels[1].affinity = Some(masks);
        reject(&t, "zero affinity mask");
        // (f) mask count != group count
        let mut t = tree.clone();
        t.levels[1].affinity = Some(vec![u64::MAX; groups1 + 1]);
        reject(&t, "mask-count mismatch");
        // (g) per-group masks with an empty intersection
        if groups1 >= 2 && n_rails >= 2 {
            let mut t = tree.clone();
            let mut masks = vec![0b01u64; groups1];
            masks[0] = 0b10;
            t.levels[1].affinity = Some(masks);
            reject(&t, "disjoint per-group masks");
        }
    }
}

/// Property: an N-level tree cut at one uniform level is EXACTLY the
/// two-level schedule — bitwise plan equality (schedule choice, modeled
/// and predicted times) between the tree planner and the legacy
/// `IntraLink` planner, and bitwise numerics + modeled-time equality
/// between `multi_level_allreduce` at depth 1 and `two_level_allreduce`.
#[test]
fn prop_one_level_tree_equivalent_to_two_level() {
    use nezha::coordinator::planner::hierarchical::{
        multi_level_allreduce, two_level_allreduce,
    };
    let mut rng = Pcg::new(6002);
    for case in 0..CASES {
        let g = [2usize, 4, 8][rng.below(3) as usize];
        let groups = 2 + rng.below(3) as usize;
        let nodes = g * groups;
        let bw = rng.range_f64(1000.0, 8000.0);
        let setup = rng.range_f64(1.0, 30.0);
        let link = IntraLink { group_size: g, bw_mbps: bw, setup_us: setup };
        let tree = TopologyTree {
            levels: vec![TopoLevel::uniform("group", g, bw, setup)],
        };

        // (1) planner equivalence: identical selection and predictions
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Glex])
            .unwrap();
        let fab = Fabric::new(nodes, rails, CpuPool::default(), case as u64).deterministic();
        let legacy = Planner::new(Some(link.clone()));
        let treed = Planner::with_tree(tree.clone());
        let timer = Timer::new(100);
        let bytes = 1u64 << (12 + rng.below(17));
        for rail in 0..2 {
            let (sa, ta) = legacy.schedule_for(&fab, &timer, rail, bytes as f64);
            let (sb, tb) = treed.schedule_for(&fab, &timer, rail, bytes as f64);
            assert_eq!(sa, sb, "case {case} rail {rail}");
            assert_eq!(ta, tb, "case {case} rail {rail}: prediction diverged");
        }

        // (2) executable equivalence: bitwise times + numerics
        let len = 64 + rng.below(1500) as usize;
        let elem_bytes = (1u64 << (16 + rng.below(10))) as f64 / len as f64;
        let chunks = [1usize, 2, 4, 8][rng.below(4) as usize];
        let salt = rng.below(13) as usize;
        let fill = move |n: usize, i: usize| ((n * 5 + i + salt) % 11) as f32;
        let mk_fab = || {
            let rails = ClusterSpec::local().build_rails(&[ProtoKind::Tcp]).unwrap();
            // jitter ON: the schedules must draw identical sample streams
            Fabric::new(nodes, rails, CpuPool::default(), 9000 + case as u64)
        };
        let mut fab_a = mk_fab();
        let mut fab_b = mk_fab();
        fab_a.begin_op();
        fab_b.begin_op();
        let mut a = UnboundBuffer::from_fn(nodes, len, fill);
        let mut b = UnboundBuffer::from_fn(nodes, len, fill);
        let w = a.full_window();
        let oa = multi_level_allreduce(
            &mut fab_a, 0, &mut a, w, &mut RustReducer, elem_bytes, &tree, 1, chunks,
        )
        .unwrap();
        let ob = two_level_allreduce(
            &mut fab_b, 0, &mut b, w, &mut RustReducer, elem_bytes, &link, chunks,
        )
        .unwrap();
        assert_eq!(oa.time_us, ob.time_us, "case {case}: modeled time diverged");
        assert_eq!(oa.bytes_moved, ob.bytes_moved, "case {case}");
        assert_eq!(oa.steps, ob.steps, "case {case}");
        for n in 0..nodes {
            assert_eq!(a.node(n), b.node(n), "case {case} node {n}: numerics diverged");
        }
    }
}

/// Property: cross-bucket pipelining is bounded — never worse than the
/// serial sum, never better than the longest single op.
#[test]
fn prop_pipelined_total_bounded() {
    let mut rng = Pcg::new(2003);
    for case in 0..CASES {
        let k = 1 + rng.below(12) as usize;
        let ops: Vec<(f64, bool)> = (0..k)
            .map(|_| (rng.range_f64(1.0, 1e5), rng.f64() < 0.6))
            .collect();
        let serial: f64 = ops.iter().map(|(t, _)| *t).sum();
        let longest = ops.iter().map(|(t, _)| *t).fold(0.0f64, f64::max);
        let overlap = rng.range_f64(0.0, 1.0);
        let t = pipeline::pipelined_total_us(&ops, overlap);
        assert!(t <= serial + 1e-9, "case {case}: {t} > serial {serial}");
        assert!(t >= longest - 1e-9, "case {case}: {t} < longest {longest}");
    }
}

/// Property: pooled-buffer allreduce results are BIT-IDENTICAL to
/// fresh-allocation results, across plan types (planner auto / static-cost
/// / forced flat dispatch), combos (ring-ring, ring-rdma, ring-sharp),
/// node counts and payload sizes — the pooling/scratch correctness
/// invariant behind the allocation-free data plane.
#[test]
fn prop_pooled_allreduce_bit_identical_to_fresh() {
    use nezha::config::{Config, PlannerMode, Policy};
    use nezha::coordinator::buffer::BufferPool;
    use nezha::coordinator::multirail::MultiRail;
    let combos: [&[ProtoKind]; 3] = [
        &[ProtoKind::Tcp, ProtoKind::Tcp],
        &[ProtoKind::Tcp, ProtoKind::Glex],
        &[ProtoKind::Tcp, ProtoKind::Sharp],
    ];
    let modes = [PlannerMode::Auto, PlannerMode::StaticCost, PlannerMode::Flat];
    let mut rng = Pcg::new(4001);
    for case in 0..24 {
        let combo = combos[rng.below(3) as usize];
        let nodes = [2usize, 4, 8][rng.below(3) as usize];
        let len = 64 + rng.below(2000) as usize;
        let mut cfg = Config {
            nodes,
            combo: combo.to_vec(),
            policy: Policy::Nezha,
            deterministic: true,
            ..Config::default()
        };
        cfg.planner = modes[rng.below(3) as usize];
        let elem_bytes = (1u64 << (16 + rng.below(11))) as f64 / len as f64;
        let mut fresh_mr = MultiRail::new(&cfg).unwrap();
        let mut pooled_mr = MultiRail::new(&cfg).unwrap();
        let mut pool = BufferPool::new();
        let salt = rng.below(13) as usize;
        let fill = move |n: usize, i: usize| ((n * 7 + i + salt) % 13) as f32;
        // several ops per case so the pooled arm actually recycles buffers
        for op in 0..4 {
            let mut fb = UnboundBuffer::from_fn(nodes, len, fill);
            fresh_mr.allreduce_scaled(&mut fb, elem_bytes).unwrap();
            let mut pb = pool.acquire(nodes, len, fill);
            pooled_mr.allreduce_scaled(&mut pb, elem_bytes).unwrap();
            for n in 0..nodes {
                assert_eq!(
                    fb.node(n),
                    pb.node(n),
                    "case {case} op {op} node {n}: pooled result diverged"
                );
            }
            pool.release(pb);
        }
    }
}

/// Regression: the scratch-reuse window splitters (`split_fractions_into`,
/// `split_chunks_into`, `split_uniform_into`) are bit-identical to their
/// allocating counterparts on edge windows — empty, len < parts, rounding
/// drift — and on random windows/fractions.
#[test]
fn prop_split_into_matches_allocating_split() {
    let mut rng = Pcg::new(4002);
    let mut cases: Vec<(usize, usize)> =
        vec![(0, 0), (10, 0), (0, 1), (7, 3), (0, 7), (3, 61), (5, 1_000_003)];
    for _ in 0..CASES {
        cases.push((rng.below(5000) as usize, rng.below(300_000) as usize));
    }
    let mut out = Vec::new();
    for (off, len) in cases {
        let w = Window::new(off, len);
        for parts in [1usize, 2, 3, 5, 8, 16, 61] {
            let fracs = vec![1.0 / parts as f64; parts];
            let alloc = w.split_fractions(&fracs);
            w.split_fractions_into(&fracs, &mut out);
            assert_eq!(alloc, out, "{w:?} fractions x{parts}");
            w.split_uniform_into(parts, &mut out);
            assert_eq!(alloc, out, "{w:?} uniform x{parts}");
        }
        for chunk in [1usize, 2, 7, 64, 1023] {
            let alloc = w.split_chunks(chunk);
            w.split_chunks_into(chunk, &mut out);
            assert_eq!(alloc, out, "{w:?} chunks of {chunk}");
        }
        // random (normalized) fractions with rounding drift
        let k = 1 + rng.below(6) as usize;
        let mut fracs: Vec<f64> = (0..k).map(|_| rng.f64().max(1e-6)).collect();
        let s: f64 = fracs.iter().sum();
        for f in &mut fracs {
            *f /= s;
        }
        let alloc = w.split_fractions(&fracs);
        w.split_fractions_into(&fracs, &mut out);
        assert_eq!(alloc, out, "{w:?} random fractions {fracs:?}");
    }
}

/// Property: the fused `reduce_copy` kernel equals add-then-copy for
/// random lengths (including non-multiple-of-8 tails) and values.
#[test]
fn prop_reduce_copy_equals_add_then_copy() {
    let mut rng = Pcg::new(4003);
    for case in 0..CASES {
        let len = rng.below(4000) as usize;
        let src: Vec<f32> = (0..len).map(|_| rng.range(-64, 64) as f32 * 0.25).collect();
        let mut d_fused: Vec<f32> = (0..len).map(|_| rng.range(-64, 64) as f32 * 0.5).collect();
        let mut d_plain = d_fused.clone();
        let mut fwd: Vec<f32> = (0..len).map(|_| rng.range(-8, 8) as f32).collect();
        let mut r = RustReducer;
        r.reduce_copy(&mut d_fused, &src, &mut fwd);
        r.add_into(&mut d_plain, &src);
        assert_eq!(d_fused, d_plain, "case {case} len {len}");
        assert_eq!(fwd, d_plain, "case {case} len {len}: forward diverged");
    }
}

/// Property: bucketizer covers the flat vector exactly, in order, for
/// random parameter layouts.
#[test]
fn prop_bucketizer_partition() {
    use nezha::trainer::bucket::Bucketizer;
    let mut rng = Pcg::new(1008);
    for case in 0..CASES {
        let k = 1 + rng.below(20) as usize;
        let sizes: Vec<usize> = (0..k).map(|_| 1 + rng.below(50_000) as usize).collect();
        let total: usize = sizes.iter().sum();
        let cap = 1 + rng.below(60_000) as usize;
        let b = Bucketizer::aligned(&sizes, cap);
        assert_eq!(b.total(), total, "case {case}");
        let mut off = 0;
        for w in &b.windows {
            assert_eq!(w.offset, off, "case {case}: non-contiguous");
            assert!(w.len > 0);
            off = w.end();
        }
    }
}

/// Property: the parallel per-rail execution engine is BIT-IDENTICAL to
/// serial execution — numerics AND modeled times — across plan types
/// (planner auto / static-cost / forced flat dispatch), combos
/// (ring-ring, ring-rdma, ring-sharp), clusters (flat and pods: flat /
/// chunked / halving-doubling / hierarchical two-level schedules all get
/// exercised), node counts, payload sizes, and with jitter ON (the
/// per-rail RNG-stream guarantee, not just disjoint windows).
#[test]
fn prop_parallel_exec_bit_identical_to_serial() {
    use nezha::config::{Config, PlannerMode, Policy};
    use nezha::coordinator::multirail::MultiRail;
    use nezha::net::cpu_pool::ExecMode;
    let combos: [&[ProtoKind]; 3] = [
        &[ProtoKind::Tcp, ProtoKind::Tcp],
        &[ProtoKind::Tcp, ProtoKind::Glex],
        &[ProtoKind::Tcp, ProtoKind::Sharp],
    ];
    let modes = [PlannerMode::Auto, PlannerMode::StaticCost, PlannerMode::Flat];
    let mut rng = Pcg::new(5001);
    for case in 0..16 {
        let combo = combos[rng.below(3) as usize];
        // pods clusters (16 nodes, groups of 4) enable two-level
        // schedules; 8-node flat clusters enable halving-doubling
        let (cluster, nodes) = if rng.f64() < 0.4 {
            (ClusterSpec::pods(4), 16usize)
        } else {
            (ClusterSpec::local(), [4usize, 8][rng.below(2) as usize])
        };
        let len = 64 + rng.below(2000) as usize;
        let mut cfg = Config {
            cluster,
            nodes,
            combo: combo.to_vec(),
            policy: Policy::Nezha,
            deterministic: rng.f64() < 0.5, // half the cases keep jitter ON
            seed: 1000 + case as u64,
            exec: ExecMode::Serial,
            ..Config::default()
        };
        cfg.planner = modes[rng.below(3) as usize];
        let mut serial = MultiRail::new(&cfg).unwrap();
        cfg.exec = ExecMode::Parallel;
        let mut parallel = MultiRail::new(&cfg).unwrap();
        // large modeled payloads keep the balancer hot → ≥2 live rails
        let elem_bytes = (1u64 << (21 + rng.below(7))) as f64 / len as f64;
        let salt = rng.below(13) as usize;
        let fill = move |n: usize, i: usize| ((n * 7 + i + salt) % 13) as f32;
        for op in 0..3 {
            let mut sb = UnboundBuffer::from_fn(nodes, len, fill);
            let mut pb = UnboundBuffer::from_fn(nodes, len, fill);
            let rs = serial.allreduce_scaled(&mut sb, elem_bytes).unwrap();
            let rp = parallel.allreduce_scaled(&mut pb, elem_bytes).unwrap();
            assert_eq!(
                rs.total_us, rp.total_us,
                "case {case} op {op}: modeled time diverged"
            );
            assert_eq!(rs.per_rail.len(), rp.per_rail.len(), "case {case} op {op}");
            for (a, b) in rs.per_rail.iter().zip(&rp.per_rail) {
                assert_eq!(a.rail, b.rail, "case {case} op {op}");
                assert_eq!(a.bytes, b.bytes, "case {case} op {op} rail {}", a.rail);
                assert_eq!(a.time_us, b.time_us, "case {case} op {op} rail {}", a.rail);
            }
            for n in 0..nodes {
                assert_eq!(
                    sb.node(n),
                    pb.node(n),
                    "case {case} op {op} node {n}: numerics diverged"
                );
            }
        }
    }
}

/// Property: a rail that is BOTH crash-downed and degraded behaves
/// bit-identically to the same rail crash-downed alone. Degradation
/// inside a down window is unobservable — `poll_health` short-circuits
/// before any loss/brownout/stall sampling — so composing hazards never
/// changes failover timing, health bookkeeping or numerics.
#[test]
fn prop_down_plus_degraded_equals_down() {
    use nezha::config::{Config, Policy};
    use nezha::coordinator::multirail::MultiRail;
    use nezha::net::fault::{DegradeSchedule, FaultSchedule};
    let mut rng = Pcg::new(7001);
    for case in 0..12 {
        let start = rng.range_f64(0.0, 100_000.0);
        let dur = rng.range_f64(100_000.0, 300_000.0);
        // the degrade window sits strictly inside the down window, so
        // every instant with degradation active is also a down instant
        let (ds, de) = (start + 0.1 * dur, start + 0.9 * dur);
        let degrade = match rng.below(3) {
            0 => DegradeSchedule::none().loss(1, ds, de, rng.range_f64(0.05, 0.5)),
            1 => DegradeSchedule::none().brownout(1, ds, de, rng.range_f64(0.3, 0.9)),
            _ => DegradeSchedule::none().stall(1, ds, de, rng.range_f64(1_000.0, 5_000.0), 0.2),
        };
        let cfg = Config {
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: case % 2 == 0, // half the cases keep jitter ON
            seed: 7100 + case as u64,
            faults: FaultSchedule::none().with(1, start, start + dur),
            ..Config::default()
        };
        let mut down = MultiRail::new(&cfg).unwrap();
        let mut both = MultiRail::new(&cfg).unwrap().with_degrade(degrade);
        let len = 2048;
        let elem_bytes = (8u64 << 20) as f64 / len as f64;
        let fill = |n: usize, i: usize| ((n + 1) * (i % 13 + 1)) as f32;
        for op in 0..10 {
            let mut a = UnboundBuffer::from_fn(4, len, fill);
            let mut b = UnboundBuffer::from_fn(4, len, fill);
            let ra = down.allreduce_scaled(&mut a, elem_bytes).unwrap();
            let rb = both.allreduce_scaled(&mut b, elem_bytes).unwrap();
            assert_eq!(ra.total_us, rb.total_us, "case {case} op {op}: modeled time diverged");
            assert_eq!(ra.failovers, rb.failovers, "case {case} op {op}");
            for (x, y) in ra.per_rail.iter().zip(&rb.per_rail) {
                assert_eq!(x.time_us, y.time_us, "case {case} op {op} rail {}", x.rail);
                assert_eq!(x.bytes, y.bytes, "case {case} op {op} rail {}", x.rail);
            }
            for n in 0..4 {
                assert_eq!(a.node(n), b.node(n), "case {case} op {op} node {n}");
            }
        }
        assert_eq!(down.fab.rails[1].health, both.fab.rails[1].health, "case {case}");
        assert_eq!(
            down.exceptions.failover_count(),
            both.exceptions.failover_count(),
            "case {case}"
        );
        assert_eq!(down.exceptions.gray_count(), both.exceptions.gray_count(), "case {case}");
        assert_eq!(
            down.fab.retries_on(1),
            both.fab.retries_on(1),
            "case {case}: retries were sampled inside a down window"
        );
    }
}

/// Property: retransmit sampling is a pure function of (seed, rail,
/// op_epoch) — identically-configured runs draw identical retry
/// sequences, and the serial and parallel executors agree bit-for-bit
/// on modeled times, retry ledgers and reduced buffers, for random
/// seeds and loss rates.
#[test]
fn prop_retry_sampling_deterministic_and_exec_invariant() {
    use nezha::config::{Config, Policy};
    use nezha::coordinator::multirail::MultiRail;
    use nezha::net::cpu_pool::ExecMode;
    use nezha::net::fault::DegradeSchedule;
    let mut rng = Pcg::new(7002);
    for case in 0..10 {
        let seed = rng.next_u64();
        // rail 1 always lossy; rail 0 mildly lossy half the time (it
        // must stay alive as the failover survivor)
        let mut degrade = DegradeSchedule::none().loss(1, 0.0, 1e12, rng.range_f64(0.02, 0.15));
        if rng.f64() < 0.5 {
            degrade = degrade.loss(0, 0.0, 1e12, rng.range_f64(0.005, 0.05));
        }
        let mut cfg = Config {
            nodes: [2usize, 4][rng.below(2) as usize],
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: rng.f64() < 0.5,
            seed,
            exec: ExecMode::Serial,
            ..Config::default()
        };
        let len = 2048;
        let elem_bytes = (8u64 << 20) as f64 / len as f64;
        let nodes = cfg.nodes;
        let run = |cfg: &Config| {
            let mut mr = MultiRail::new(cfg).unwrap().with_degrade(degrade.clone());
            let mut trace = Vec::new();
            let mut node0 = Vec::new();
            for _ in 0..5 {
                let mut buf =
                    UnboundBuffer::from_fn(nodes, len, |n, i| ((n + 1) * (i % 13 + 1)) as f32);
                let rep = mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
                trace.push((rep.total_us, mr.fab.retries_on(0), mr.fab.retries_on(1)));
                node0 = buf.node(0).to_vec();
            }
            (trace, node0)
        };
        let first = run(&cfg);
        let second = run(&cfg);
        assert_eq!(first, second, "case {case} (seed {seed}): reruns diverged");
        cfg.exec = ExecMode::Parallel;
        let parallel = run(&cfg);
        assert_eq!(first, parallel, "case {case} (seed {seed}): executors diverged");
        let (_, r0, r1) = *first.0.last().unwrap();
        assert!(r0 + r1 > 0, "case {case}: loss never charged a retry");
    }
}

/// Property: a quarantine that lands mid-run on an affinity-constrained
/// pods cluster never routes payload outside the strict per-pod rail
/// intersection — before, during or after the §4.4 failover and the
/// probationary readmission — and the quarantined rail rejoins the plan
/// once it settles back to Healthy.
#[test]
fn prop_quarantine_respects_affinity_masks() {
    use nezha::config::{Config, Policy};
    use nezha::coordinator::multirail::MultiRail;
    use nezha::net::fault::FaultSchedule;
    use nezha::net::rail::RailHealth;
    let mut rng = Pcg::new(7003);
    for case in 0..8 {
        // 2 pods of 4 nodes on 3 rails; every pod admits rails {0, 2},
        // rail 1 per-pod at random — rail 0 survives every hazard, and
        // the crash window quarantines rail 2 mid-campaign
        let masks: Vec<u64> = (0..2).map(|_| 0b101 | (rng.below(2) << 1)).collect();
        let allowed: u64 = masks.iter().fold(0b111, |a, m| a & m);
        let start = rng.range_f64(0.0, 30_000.0);
        let end = start + rng.range_f64(60_000.0, 160_000.0);
        let mut cfg = Config {
            nodes: 8,
            combo: vec![ProtoKind::Tcp; 3],
            policy: Policy::Nezha,
            deterministic: true,
            seed: 7300 + case as u64,
            faults: FaultSchedule::none().with(2, start, end),
            ..Config::default()
        };
        cfg.cluster = ClusterSpec::pods(4).with_affinity(0, masks);
        let mut mr = MultiRail::new(&cfg).unwrap();
        let len = 2048;
        let elem_bytes = (24u64 << 20) as f64 / len as f64; // hot on every admitted rail
        let fill = |n: usize, i: usize| ((n + 1) * (i % 13 + 1)) as f32;
        let mut saw_quarantine = false;
        let mut settled = false;
        for op in 0..24 {
            let before = mr.fab.rails[2].health;
            let mut buf = UnboundBuffer::from_fn(8, len, fill);
            let rep = mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
            for i in 0..len {
                // sum over nodes of (n+1) = 36 for 8 nodes
                assert_eq!(buf.node(0)[i], (36 * (i % 13 + 1)) as f32, "case {case} op {op}");
            }
            let after = mr.fab.rails[2].health;
            for s in rep.per_rail.iter().filter(|s| s.bytes > 0) {
                assert!(
                    allowed & (1 << s.rail) != 0,
                    "case {case} op {op}: rail {} carried payload outside the affinity intersection",
                    s.rail
                );
                if before == RailHealth::Quarantined && after == RailHealth::Quarantined {
                    assert_ne!(s.rail, 2, "case {case} op {op}: quarantined rail carried payload");
                }
            }
            if after == RailHealth::Quarantined {
                saw_quarantine = true;
            }
            if saw_quarantine && after == RailHealth::Healthy && mr.fab.now_us() > end {
                settled = true;
                break;
            }
        }
        assert!(saw_quarantine, "case {case}: the crash window must quarantine rail 2");
        assert!(settled, "case {case}: rail 2 never readmitted to Healthy");
        // the readmitted rail rejoins the plan within a few hot ops
        let mut carried = false;
        for _ in 0..3 {
            let mut buf = UnboundBuffer::from_fn(8, len, fill);
            let rep = mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
            carried |= rep.per_rail.iter().any(|s| s.rail == 2 && s.bytes > 0);
        }
        assert!(carried, "case {case}: the readmitted rail never rejoined the plan");
    }
}

/// Property: run-to-run determinism of the parallel executor — two
/// identically-seeded coordinators produce identical modeled-time
/// sequences under jitter, however the OS schedules the worker threads
/// (per-rail streams are a pure function of (seed, rail, op_epoch)).
#[test]
fn prop_parallel_exec_deterministic_across_runs() {
    use nezha::config::{Config, Policy};
    use nezha::coordinator::multirail::MultiRail;
    use nezha::net::cpu_pool::ExecMode;
    let mut rng = Pcg::new(5002);
    for case in 0..8 {
        let seed = rng.next_u64();
        let nodes = [4usize, 8][rng.below(2) as usize];
        let len = 128 + rng.below(1000) as usize;
        let cfg = Config {
            nodes,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: false, // jitter ON: the sampled times must match
            seed,
            exec: ExecMode::Parallel,
            ..Config::default()
        };
        let run = |cfg: &Config| -> Vec<f64> {
            let mut mr = MultiRail::new(cfg).unwrap();
            let elem_bytes = (8u64 << 20) as f64 / len as f64;
            (0..5)
                .map(|_| {
                    let mut buf = UnboundBuffer::from_fn(nodes, len, |n, i| ((n + i) % 7) as f32);
                    mr.allreduce_scaled(&mut buf, elem_bytes).unwrap().total_us
                })
                .collect()
        };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a, b, "case {case} (seed {seed}): runs diverged");
        assert!(a.iter().all(|t| *t > 0.0), "case {case}");
    }
}

/// Property: a rail that is BOTH crash-downed and corrupting behaves
/// bit-identically to the same rail crash-downed alone. A down rail
/// transfers nothing, so there is nothing to corrupt — the down check
/// precedes every corruption draw — and that holds with the wire
/// checksums on or off.
#[test]
fn prop_down_plus_corrupt_equals_down() {
    use nezha::config::{Config, Policy};
    use nezha::coordinator::multirail::MultiRail;
    use nezha::net::fault::{CorruptSchedule, FaultSchedule};
    let mut rng = Pcg::new(8001);
    for case in 0..12 {
        let start = rng.range_f64(0.0, 100_000.0);
        let dur = rng.range_f64(100_000.0, 300_000.0);
        // the corrupt window sits strictly inside the down window, so
        // every instant with corruption active is also a down instant
        let (cs, ce) = (start + 0.1 * dur, start + 0.9 * dur);
        let p = rng.range_f64(0.1, 0.9);
        let corrupt = match rng.below(4) {
            0 => CorruptSchedule::none().flip(1, cs, ce, p),
            1 => CorruptSchedule::none().dup(1, cs, ce, p),
            2 => CorruptSchedule::none().trunc(1, cs, ce, p),
            _ => CorruptSchedule::none().stuck(1, cs, ce, p),
        };
        let mut cfg = Config {
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: case % 2 == 0, // half the cases keep jitter ON
            seed: 8100 + case as u64,
            faults: FaultSchedule::none().with(1, start, start + dur),
            ..Config::default()
        };
        cfg.integrity = case % 3 != 0; // exercise both checksum modes
        let mut down = MultiRail::new(&cfg).unwrap();
        let mut both = MultiRail::new(&cfg).unwrap().with_corrupt(corrupt);
        let len = 2048;
        let elem_bytes = (8u64 << 20) as f64 / len as f64;
        let fill = |n: usize, i: usize| ((n + 1) * (i % 13 + 1)) as f32;
        for op in 0..10 {
            let mut a = UnboundBuffer::from_fn(4, len, fill);
            let mut b = UnboundBuffer::from_fn(4, len, fill);
            let ra = down.allreduce_scaled(&mut a, elem_bytes).unwrap();
            let rb = both.allreduce_scaled(&mut b, elem_bytes).unwrap();
            assert_eq!(ra.total_us, rb.total_us, "case {case} op {op}: modeled time diverged");
            assert_eq!(ra.failovers, rb.failovers, "case {case} op {op}");
            for n in 0..4 {
                assert_eq!(a.node(n), b.node(n), "case {case} op {op} node {n}");
            }
        }
        assert_eq!(down.fab.rails[1].health, both.fab.rails[1].health, "case {case}");
        assert_eq!(
            down.exceptions.failover_count(),
            both.exceptions.failover_count(),
            "case {case}"
        );
        assert_eq!(
            both.fab.corruptions_on(1),
            0,
            "case {case}: corruption was sampled inside a down window"
        );
        assert_eq!(
            down.fab.retries_on(1),
            both.fab.retries_on(1),
            "case {case}: retransmits were recharged inside a down window"
        );
    }
}

/// Property: corruption sampling is a pure function of (seed, rail,
/// op_epoch) — identically-configured runs draw identical corruption
/// sequences, and the serial and parallel executors agree bit-for-bit on
/// modeled times, the unified retry ledger, the corruption ledger and the
/// reduced buffers, with the wire checksums on or off.
#[test]
fn prop_corruption_sampling_deterministic_and_exec_invariant() {
    use nezha::config::{Config, Policy};
    use nezha::coordinator::multirail::MultiRail;
    use nezha::net::cpu_pool::ExecMode;
    use nezha::net::fault::CorruptSchedule;
    let mut rng = Pcg::new(8002);
    for case in 0..10 {
        let seed = rng.next_u64();
        let integrity = rng.f64() < 0.5;
        // rail 1 carries a persistent storm, sometimes with a second
        // windowed kind composed on top
        let mut corrupt = CorruptSchedule::none().flip(1, 0.0, 1e12, rng.range_f64(0.02, 0.12));
        if rng.f64() < 0.5 {
            corrupt = corrupt.dup(1, rng.range_f64(0.0, 50_000.0), 1e9, rng.range_f64(0.01, 0.05));
        }
        let mut cfg = Config {
            nodes: [2usize, 4][rng.below(2) as usize],
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: rng.f64() < 0.5,
            seed,
            exec: ExecMode::Serial,
            ..Config::default()
        };
        cfg.integrity = integrity;
        let len = 2048;
        let elem_bytes = (8u64 << 20) as f64 / len as f64;
        let nodes = cfg.nodes;
        let run = |cfg: &Config| {
            let mut mr = MultiRail::new(cfg).unwrap().with_corrupt(corrupt.clone());
            let mut trace = Vec::new();
            let mut node0 = Vec::new();
            for _ in 0..5 {
                let mut buf =
                    UnboundBuffer::from_fn(nodes, len, |n, i| ((n + 1) * (i % 13 + 1)) as f32);
                let rep = mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
                trace.push((rep.total_us, mr.fab.retries_on(1), mr.fab.corruptions_on(1)));
                node0 = buf.node(0).to_vec();
            }
            (trace, node0)
        };
        let first = run(&cfg);
        let second = run(&cfg);
        assert_eq!(first, second, "case {case} (seed {seed}): reruns diverged");
        cfg.exec = ExecMode::Parallel;
        let parallel = run(&cfg);
        assert_eq!(first, parallel, "case {case} (seed {seed}): executors diverged");
        let (_, retries, corruptions) = *first.0.last().unwrap();
        assert!(corruptions > 0, "case {case} (seed {seed}): the storm never corrupted");
        if integrity {
            assert!(
                retries > 0,
                "case {case}: detected corruption must recharge retransmits"
            );
        } else {
            assert_eq!(retries, 0, "case {case}: silent corruption must not charge retries");
        }
    }
}

/// Property: the persistent worker pool's prioritized drain is
/// result-deterministic — for random job counts and random priorities,
/// results always come back in submission order with the right values,
/// run after run, however the OS schedules the workers.
#[test]
fn prop_pool_prioritized_results_submission_ordered_and_deterministic() {
    use nezha::net::cpu_pool::{ExecMode, RailExecutor};
    let mut rng = Pcg::new(9001);
    let ex = RailExecutor::new(ExecMode::Parallel);
    for case in 0..CASES {
        let n = 1 + rng.below(24) as usize;
        let prios: Vec<u32> = (0..n).map(|_| rng.below(8) as u32).collect();
        let run = |prios: &[u32]| -> Vec<usize> {
            let jobs: Vec<(u32, _)> = prios
                .iter()
                .enumerate()
                .map(|(i, &p)| (p, move || i * 7 + 1))
                .collect();
            ex.run_prioritized(jobs)
        };
        let expect: Vec<usize> = (0..n).map(|i| i * 7 + 1).collect();
        let a = run(&prios);
        assert_eq!(a, expect, "case {case}: results left submission order");
        let b = run(&prios);
        assert_eq!(a, b, "case {case}: reruns diverged");
    }
}

/// Property: priority scheduling is bit-identical to the barrier baseline
/// on random synthetic models — random bucket counts, random bucket
/// sizes, random compute speeds. The collectives run in the same program
/// order either way (same op epochs, same per-rail RNG streams), so every
/// measured iteration's gradient fingerprints must match exactly, and the
/// wire timeline must always drain. (No time-ordering claim here: random
/// profiles may be compute-bound, where barrier's overlap credit wins.)
#[test]
fn prop_priority_sched_bit_identical_on_random_profiles() {
    use nezha::config::{Config, Policy};
    use nezha::net::cpu_pool::SchedMode;
    use nezha::trainer::{CommProfile, DdpSim};
    let mut rng = Pcg::new(9002);
    for case in 0..12 {
        let k = 2 + rng.below(8) as usize;
        let ops: Vec<u64> = (0..k).map(|_| 1u64 << (18 + rng.below(6))).collect();
        let sps = rng.range_f64(50.0, 2000.0);
        let jitter = rng.f64() < 0.5;
        let mut cfg = Config {
            nodes: [2usize, 4][rng.below(2) as usize],
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: !jitter, // half the cases keep jitter ON
            seed: 9100 + case as u64,
            ..Config::default()
        };
        let mut barrier =
            DdpSim::new(&cfg, CommProfile::synthetic("fuzz", ops.clone(), sps), 1, 32).unwrap();
        cfg.sched = SchedMode::Priority;
        let mut priority =
            DdpSim::new(&cfg, CommProfile::synthetic("fuzz", ops, sps), 1, 32).unwrap();
        barrier.warmup(2).unwrap();
        priority.warmup(2).unwrap();
        for it in 0..3 {
            let bt = barrier.iter_time_us().unwrap();
            let pt = priority.iter_time_us().unwrap();
            assert!(bt > 0.0 && pt > 0.0, "case {case} iter {it}");
            assert_eq!(
                barrier.last_fingerprints(),
                priority.last_fingerprints(),
                "case {case} iter {it} (k={k}): gradients diverged"
            );
        }
        assert_eq!(priority.sched_stats().ops_enqueued, 5 * k as u64, "case {case}");
        assert!(priority.drain_queue(), "case {case}: timeline left a stuck op");
    }
}

/// Property: the FNV-1a integrity checksum detects every single-bit flip
/// at any position, for windows up to 64 MiB (16M f32 words). Each absorb
/// step is a bijection in the running hash, so one changed word always
/// changes the digest — this samples that guarantee across the ladder.
#[test]
fn prop_checksum_detects_single_bit_flips_to_64mib() {
    use nezha::coordinator::collective::checksum;
    let mut rng = Pcg::new(8003);
    for &len in &[1usize, 5, 1 << 10, (1 << 14) + 3, 1 << 20, 1 << 24] {
        let data: Vec<f32> = (0..len).map(|i| ((i % 251) as f32) * 0.5 - 31.0).collect();
        let base = checksum(&data);
        let flips = if len >= 1 << 20 { 4 } else { 16 };
        for _ in 0..flips {
            let elem = rng.below(len as u64) as usize;
            let bit = rng.below(32) as u32;
            let mut d = data.clone();
            d[elem] = f32::from_bits(d[elem].to_bits() ^ (1 << bit));
            assert_ne!(checksum(&d), base, "len {len} elem {elem} bit {bit} undetected");
        }
    }
}
